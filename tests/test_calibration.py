"""Cost-model calibration observability (DESIGN.md §15): the
predicted-vs-observed store, corrections threading through the flow
solver and the warm-started re-solve, the damped miscalibration
trigger, the metrics endpoint, and the sim-vs-runtime parity surface."""
import json
import types
import urllib.request

import pytest

from repro.core import LLAMA2_70B, WORKLOADS, reschedule, schedule
from repro.core.cluster import kv_skewed_setting
from repro.core.cost_model import (CALIBRATION_SURFACES, CORRECTION_MAX,
                                   CORRECTION_MIN, CostCorrections)
from repro.serving import (CalibrationStore, FleetController, FleetSpec,
                           MetricsEndpoint, Request, RequestState, Router,
                           TraceRecorder, calibration_workload,
                           mixed_priority_workload, prometheus_text,
                           simulate, simulate_fleet)
from repro.serving.calibration import (_RATIO_HI, _RATIO_LO,
                                       placement_predictor, plan_predictor)


def _done_request(rid=0, s_in=64, s_out=4, *, prefill=0.5, transfer=0.2,
                  decode_step=0.1, warmup=0.0):
    """A DONE request with an exact synthetic stage timeline."""
    r = Request(rid=rid, s_in=s_in, s_out=s_out, arrival=0.0)
    t = 1.0
    r.advance(RequestState.PREFILLING, t)
    t += prefill
    r.advance(RequestState.KV_TRANSFER, t)
    t += transfer
    r.advance(RequestState.DECODING, t)
    t += decode_step * (s_out - 1)
    r.tokens_out = s_out
    r.warmup_penalty_s = warmup
    r.advance(RequestState.DONE, t)
    return r


def _const_predictor(**pred):
    return lambda req, group: dict(pred)


# -- store math -------------------------------------------------------------

def test_stamp_then_observe_scores_exact_ratios():
    store = CalibrationStore(
        _const_predictor(prefill=0.25, decode=0.05, transfer=0.1),
        min_observations=1)
    req = _done_request(prefill=0.5, transfer=0.2, decode_step=0.1)
    store.stamp(req, group=3)
    assert req.pred_prefill_s == 0.25 and req.pred_transfer_s == 0.1
    store.observe(req)
    f = store.factors()
    # every surface observed at exactly 2x its prediction
    assert f == pytest.approx({"prefill": 2.0, "decode": 2.0,
                               "transfer": 2.0})
    snap = store.snapshot()
    # per-group cell AND the global -1 aggregate, same first fold
    assert snap[("prefill", 3)]["ratio"] == pytest.approx(2.0)
    assert snap[("prefill", -1)]["ratio"] == pytest.approx(2.0)
    assert snap[("prefill", 3)]["residual_s"] == pytest.approx(0.25)
    assert store.observations == 1 and store.stamped == 1


def test_ratio_clamped_before_folding():
    store = CalibrationStore(_const_predictor(prefill=1e-3),
                             min_observations=1)
    req = _done_request(prefill=10.0)          # raw ratio 10000x
    store.stamp(req, 0)
    store.observe(req)
    assert store.factors()["prefill"] == pytest.approx(_RATIO_HI)
    store2 = CalibrationStore(_const_predictor(prefill=100.0),
                              min_observations=1)
    req2 = _done_request(rid=1, prefill=0.01)  # raw ratio 1e-4
    store2.stamp(req2, 0)
    store2.observe(req2)
    assert store2.factors()["prefill"] == pytest.approx(_RATIO_LO)


def test_min_observations_gates_factors_and_warmup():
    store = CalibrationStore(_const_predictor(prefill=0.5),
                             min_observations=3)
    for i in range(2):
        req = _done_request(rid=i)
        store.stamp(req, 0)
        store.observe(req)
    assert store.factors() == {} and not store.warmed_up
    assert store.max_error() == 0.0 and not store.miscalibrated()
    req = _done_request(rid=2)
    store.stamp(req, 0)
    store.observe(req)
    assert store.warmed_up and "prefill" in store.factors()


def test_absent_surfaces_never_scored():
    # single-token request: no decode cadence; zero warm-up: no warmup
    store = CalibrationStore(
        _const_predictor(prefill=0.5, decode=0.1, warmup=0.0),
        min_observations=1)
    req = Request(rid=0, s_in=8, s_out=1, arrival=0.0)
    req.advance(RequestState.PREFILLING, 1.0)
    req.tokens_out = 1
    req.advance(RequestState.DONE, 1.5)        # §8 single-token shortcut
    store.stamp(req, 0)
    store.observe(req)
    assert set(store.factors()) == {"prefill"}


def test_non_done_terminals_clear_but_do_not_score():
    store = CalibrationStore(_const_predictor(prefill=0.5),
                             min_observations=1)
    req = Request(rid=0, s_in=8, s_out=4, arrival=0.0)
    store.stamp(req, 0)
    req.advance(RequestState.CANCELLED, 1.0)
    store.observe(req)
    assert store.observations == 0 and store.factors() == {}
    assert store._routed == {}


def test_ewma_folds_toward_new_ratio():
    store = CalibrationStore(_const_predictor(prefill=0.5),
                             ewma_alpha=0.5, min_observations=1)
    for i, obs in enumerate([0.5, 1.0]):       # ratios 1.0 then 2.0
        req = _done_request(rid=i, prefill=obs)
        store.stamp(req, 0)
        store.observe(req)
    assert store.factors()["prefill"] == pytest.approx(1.5)


def test_observe_emits_cost_error_events_and_gauges():
    rec = TraceRecorder()
    store = CalibrationStore(_const_predictor(prefill=0.25),
                             min_observations=1, recorder=rec)
    req = _done_request(prefill=0.5)
    store.stamp(req, 2)
    store.observe(req, ts=7.0)
    kinds = [e.kind for e in rec.events]
    assert "cost_error" in kinds
    err = next(e for e in rec.events if e.kind == "cost_error")
    assert err.track == "replica:2"
    assert dict(err.args)["prefill_ratio"] == pytest.approx(2.0)
    series = rec.series[("replica:2", "cost_ratio:prefill")]
    assert series == [(7.0, pytest.approx(2.0))]


def test_corrections_clamped_identity_and_dict():
    c = CostCorrections.from_factors(
        {"prefill": 100.0, "transfer": 1e-6, "decode": 1.3,
         "warmup": float("nan")})
    assert c.prefill == CORRECTION_MAX and c.transfer == CORRECTION_MIN
    assert c.decode == pytest.approx(1.3) and c.warmup == 1.0
    assert not c.is_identity
    assert CostCorrections().is_identity
    assert set(c.as_dict()) == set(CALIBRATION_SURFACES)


def test_prometheus_exports_cost_model_error_series():
    store = CalibrationStore(_const_predictor(prefill=0.25),
                             min_observations=1)
    req = _done_request(prefill=0.5)
    store.stamp(req, 1)
    store.observe(req)
    sim = simulate(kv_skewed_setting(0.15), LLAMA2_70B,
                   schedule(kv_skewed_setting(0.15), LLAMA2_70B,
                            WORKLOADS["LPLD"], max_refine_iters=2).placement,
                   calibration_workload(4, rate_rps=4.0))
    text = prometheus_text(sim, calibration=store)
    assert 'repro_cost_model_error{surface="prefill",group="1"}' in text
    assert 'repro_cost_model_error{surface="prefill",group="-1"}' in text


# -- corrections through the solver -----------------------------------------

@pytest.fixture(scope="module")
def believed_sched():
    cl = kv_skewed_setting(0.15)
    return cl, schedule(cl, LLAMA2_70B, WORKLOADS["HPLD"],
                        max_refine_iters=6, seed=0)


def test_corrections_reprice_the_solve(believed_sched):
    cl, sched = believed_sched
    slow = CostCorrections(prefill=2.0, decode=2.0, transfer=5.0)
    corrected = schedule(cl, LLAMA2_70B, WORKLOADS["HPLD"],
                         max_refine_iters=2, seed=0, corrections=slow)
    base = schedule(cl, LLAMA2_70B, WORKLOADS["HPLD"],
                    max_refine_iters=2, seed=0)
    # halved compute + 5x transfer must price strictly less flow
    assert corrected.placement.max_flow < base.placement.max_flow


def test_reschedule_identity_corrections_matches_plain(believed_sched):
    cl, sched = believed_sched
    plain = reschedule(cl, LLAMA2_70B, sched, WORKLOADS["HPLD"],
                       max_refine_iters=2)
    ident = reschedule(cl, LLAMA2_70B, sched, WORKLOADS["HPLD"],
                       max_refine_iters=2,
                       corrections=CostCorrections())
    assert dict(plain.placement.kv_routes) == dict(ident.placement.kv_routes)


def test_calibrated_reschedule_can_flip_group_roles(believed_sched):
    """The §15 ridge: a strong transfer correction changes WHICH edge
    binds, flipping the optimal role of a group — reachable only via
    the role-flip seeds, not via swap refinement from the stale start."""
    cl, sched = believed_sched
    store = CalibrationStore(
        placement_predictor(cl, LLAMA2_70B, sched.placement))
    simulate(kv_skewed_setting(0.05), LLAMA2_70B, sched.placement,
             calibration_workload(64, rate_rps=8.0, seed=1, slo_s=2.0),
             calibration=store)
    corr = store.corrections()
    assert corr.transfer > 1.5 and not corr.is_identity
    cal = reschedule(cl, LLAMA2_70B, sched, WORKLOADS["HPLD"],
                     corrections=corr, max_refine_iters=12)
    assert (dict(cal.placement.kv_routes).keys()
            != dict(sched.placement.kv_routes).keys())
    flips = sum(a != b for a, b in zip(sched.partition.is_prefill,
                                       cal.partition.is_prefill))
    assert flips >= 1


# -- the damped miscalibration trigger --------------------------------------

class _FakeStore:
    def __init__(self, errors):
        self.errors = list(errors)
        self.step = -1

    def tick(self):
        self.step += 1

    @property
    def warmed_up(self):
        return True

    def max_error(self):
        return self.errors[min(self.step, len(self.errors) - 1)]


def _stub_controller(spec, store):
    router = types.SimpleNamespace(replicas=[], telemetry=None,
                                   calibration=None)
    return FleetController(router, lambda slot: None, spec,
                           calibration=store)


def test_trigger_needs_sustained_error():
    spec = FleetSpec(min_replicas=1, max_replicas=1, sustain_steps=3,
                     miscal_bound=0.5, recal_cooldown_steps=4)
    store = _FakeStore([2.0, 2.0, 0.0, 2.0, 2.0, 0.0, 2.0])
    ctrl = _stub_controller(spec, store)
    for step in range(7):                      # never 3 hot in a row
        store.tick()
        ctrl._calibration_policy(step)
    assert ctrl.recalibrations == 0 and ctrl.events == []


def test_trigger_fires_once_then_respects_cooldown():
    spec = FleetSpec(min_replicas=1, max_replicas=1, sustain_steps=2,
                     miscal_bound=0.5, recal_cooldown_steps=100)
    store = _FakeStore([2.0] * 20)
    ctrl = _stub_controller(spec, store)
    for step in range(20):                     # always hot
        store.tick()
        ctrl._calibration_policy(step)
    assert ctrl.recalibrations == 1
    [ev] = ctrl.events
    assert ev.kind == "recalibrate" and ev.replica == -1
    assert "max_error=2.000" in ev.reason


def test_trigger_refires_after_cooldown_and_resolves():
    spec = FleetSpec(min_replicas=1, max_replicas=1, sustain_steps=2,
                     miscal_bound=0.5, recal_cooldown_steps=5)
    store = _FakeStore([2.0] * 20)
    seen = []
    ctrl = _stub_controller(spec, store)
    ctrl.resolver = lambda c, ev: seen.append(ev.kind) or None
    for step in range(14):
        store.tick()
        ctrl._calibration_policy(step)
    assert ctrl.recalibrations >= 2
    # every recalibrate routed through the resolver hook
    assert seen == ["recalibrate"] * ctrl.recalibrations


def test_no_bound_no_trigger():
    spec = FleetSpec(min_replicas=1, max_replicas=1, sustain_steps=1)
    assert spec.miscal_bound is None
    ctrl = _stub_controller(spec, _FakeStore([10.0] * 5))
    for step in range(5):
        ctrl._calibration_policy(step)
    assert ctrl.recalibrations == 0


def test_controller_finds_store_via_router_fallback():
    spec = FleetSpec(min_replicas=1, max_replicas=1,
                     miscal_bound=0.5)
    store = _FakeStore([0.0])
    router = types.SimpleNamespace(replicas=[], telemetry=None,
                                   calibration=store)
    ctrl = FleetController(router, lambda slot: None, spec)
    assert ctrl._calibration_store() is store


def test_fleet_sim_fires_recalibrate_event():
    """End to end in the scheduling domain: a store warmed by real
    traffic with a sustained model error drives the controller's
    trigger through ``simulate_fleet``'s router-fallback wiring."""
    cl = kv_skewed_setting(0.15)
    sched = schedule(cl, LLAMA2_70B, WORKLOADS["LPLD"],
                     max_refine_iters=2, seed=0)
    pre = next(r for r in sched.placement.prefill_replicas()
               if r.plan is not None)
    dec = next(r for r in sched.placement.decode_replicas()
               if r.plan is not None)
    store = CalibrationStore(
        plan_predictor(cl, LLAMA2_70B, pre.plan, dec.plan),
        min_observations=4)
    spec = FleetSpec(min_replicas=2, max_replicas=2, queue_high=1e9,
                     sustain_steps=3, miscal_bound=0.2,
                     recal_cooldown_steps=10 ** 6)
    res = simulate_fleet(
        mixed_priority_workload(n=40, rate_rps=40.0, seed=5,
                                out_lens=(3, 5, 8)),
        num_replicas=2, autoscale=spec, calibration=store, dt=0.05)
    recals = [e for e in res.scale_events if e[1] == "recalibrate"]
    assert len(recals) == 1 and recals[0][2] == -1
    assert store.warmed_up and store.max_error() > 0.2


# -- metrics endpoint (§15 scrape surface) ----------------------------------

def test_metrics_endpoint_serves_healthz_and_metrics():
    rendered = []

    def render():
        rendered.append(1)
        return "repro_requests_total 3\n"

    ep = MetricsEndpoint(render, port=0).start()
    base = f"http://127.0.0.1:{ep.port}"
    try:
        assert ep.port != 0 and ep.url == f"{base}/metrics"
        with urllib.request.urlopen(f"{base}/healthz", timeout=5) as r:
            assert r.status == 200 and r.read() == b"ok\n"
        with urllib.request.urlopen(ep.url, timeout=5) as r:
            assert r.status == 200
            assert b"repro_requests_total 3" in r.read()
            assert "text/plain" in r.headers["Content-Type"]
        # render is called per scrape, not cached at start
        assert len(rendered) == 1
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(f"{base}/nope", timeout=5)
        assert exc.value.code == 404
    finally:
        ep.close()


def test_metrics_endpoint_render_error_is_500_not_crash():
    def render():
        raise RuntimeError("boom")

    ep = MetricsEndpoint(render, port=0).start()
    try:
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(ep.url, timeout=5)
        assert exc.value.code == 500
    finally:
        ep.close()


# -- sim-vs-runtime parity (§15 surface) ------------------------------------

def test_sim_runtime_calibration_parity():
    """Two identically-configured stores, one fed by the simulator
    fleet and one by real Coordinators on the same seeded trace, must
    end with EXACTLY equal per-(surface, group) error state."""
    import jax

    from repro.configs import ARCHS
    from repro.models import init_params
    from repro.serving import Coordinator, CoordinatorReplica, StepClock

    cl = kv_skewed_setting(0.15)
    sched = schedule(cl, LLAMA2_70B, WORKLOADS["LPLD"],
                     max_refine_iters=2, seed=0)
    pre = next(r for r in sched.placement.prefill_replicas()
               if r.plan is not None)
    dec = next(r for r in sched.placement.decode_replicas()
               if r.plan is not None)

    def mk_store():
        return CalibrationStore(
            plan_predictor(cl, LLAMA2_70B, pre.plan, dec.plan),
            min_observations=4)

    cfg = ARCHS["qwen3-1.7b"].reduced()

    def trace():
        return mixed_priority_workload(n=10, rate_rps=100.0, seed=7,
                                       vocab=min(cfg.vocab, 256),
                                       system_lens=(8, 6, 4),
                                       user_lens=(4, 6, 8),
                                       out_lens=(3, 5, 8))

    s_sim = mk_store()
    simulate_fleet(trace(), num_replicas=2, slots_per_replica=2,
                   max_prefill_batch=2, capacity=96, dt=0.05,
                   queue_capacity=8, policy="slo", calibration=s_sim)

    params = init_params(jax.random.PRNGKey(0), cfg)
    clock = StepClock()

    def factory(_slot):
        return CoordinatorReplica(
            Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=2, capacity=96,
                        num_prefill_engines=1,
                        prefix_cache_bytes=float("inf")),
            max_prefill_batch=2, clock=clock)

    s_rt = mk_store()
    router = Router([factory(0), factory(1)], queue_capacity=8,
                    policy="slo", clock=clock, calibration=s_rt)
    router.run_trace(trace(), dt=0.05)

    assert s_sim.observations == s_rt.observations > 0
    assert s_sim.snapshot() == s_rt.snapshot()   # bitwise parity
    assert s_sim.factors() == s_rt.factors()


def test_workload_monitor_surfaces_miscalibration_signal():
    from repro.core.scheduler import WorkloadMonitor

    mon = WorkloadMonitor(WORKLOADS["LPLD"])
    assert mon.miscalibration() == 0.0         # nothing attached
    store = CalibrationStore(_const_predictor(prefill=0.25),
                             min_observations=1)
    mon.attach_calibration(store)
    assert mon.miscalibration() == 0.0         # attached but cold
    req = _done_request(prefill=0.5)
    store.stamp(req, 0)
    store.observe(req)
    assert mon.miscalibration() == pytest.approx(1.0)   # |2.0 - 1|
