"""Property tests for the §11 page allocator and copy-on-write sharing
(hypothesis; pure accounting — no JAX)."""
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving.paging import (OutOfPagesError, PagePool, PagedSlab,
                                  pages_for, pages_for_request,
                                  shareable_pages)
from repro.serving.prefix_cache import PrefixCache  # noqa: E402


# ---------------------------------------------------------------------------
# Arithmetic
# ---------------------------------------------------------------------------


@given(st.integers(0, 10_000), st.integers(1, 512))
def test_pages_for_covers_exactly(tokens, ps):
    n = pages_for(tokens, ps)
    assert n * ps >= tokens              # coverage
    assert (n - 1) * ps < tokens or n == 0   # minimality


@given(st.integers(1, 4096), st.integers(0, 1024), st.integers(1, 256))
def test_pages_for_request_bounds(s_in, s_out, ps):
    n = pages_for_request(s_in, s_out, ps)
    if s_out <= 1:
        assert n == 0                    # finishes at prefill (§8)
    else:
        assert n == pages_for(s_in + s_out - 1, ps)
        # monotone in both lengths
        assert n >= pages_for_request(s_in, max(s_out - 1, 0), ps)


@given(st.integers(0, 4096), st.integers(1, 256))
def test_shareable_pages_never_cover_the_write_page(prefix, ps):
    k = shareable_pages(prefix, ps)
    assert k * ps <= prefix              # fully below the first write
    assert (k + 1) * ps > prefix         # maximal


# ---------------------------------------------------------------------------
# PagePool state machine
# ---------------------------------------------------------------------------


ops = st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(1, 6)),
        st.tuples(st.just("release"), st.integers(0, 40)),
        st.tuples(st.just("retain"), st.integers(0, 40)),
    ),
    max_size=60)


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 48), st.integers(1, 64), ops)
def test_pool_invariants_under_random_ops(num_pages, page_size, script):
    pool = PagePool(num_pages, page_size)
    live = []                            # one entry per outstanding ref
    for op, arg in script:
        if op == "alloc":
            if arg <= pool.free_pages:
                got = pool.alloc(arg)
                assert len(set(got)) == arg
                assert pool.scratch not in got
                live.extend(got)
            else:
                with pytest.raises(OutOfPagesError):
                    pool.alloc(arg)
        elif op == "retain" and live:
            pg = live[arg % len(live)]
            pool.retain([pg])
            live.append(pg)
        elif op == "release" and live:
            pg = live.pop(arg % len(live))
            pool.release([pg])
        # invariants
        assert pool.free_pages + pool.pages_in_use == pool.num_allocatable
        assert pool.pages_in_use == len(set(live))
        for p in range(pool.num_pages):
            assert pool.refcount(p) == live.count(p) + (
                0 if p != pool.scratch else 0)
        assert 0.0 <= pool.utilization <= 1.0
    # drain: releasing every outstanding ref frees the pool
    for pg in live:
        pool.release([pg])
    assert pool.free_pages == pool.num_allocatable


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 20))
def test_double_release_is_caught(n):
    pool = PagePool(n + 1, 8)
    pages = pool.alloc(n)
    pool.release(pages)
    with pytest.raises(AssertionError):
        pool.release([pages[0]])


# ---------------------------------------------------------------------------
# PagedSlab x PrefixCache: release accounting
# ---------------------------------------------------------------------------


prompts = st.lists(
    st.lists(st.integers(0, 3), min_size=1, max_size=24),
    min_size=1, max_size=12)


@settings(max_examples=40, deadline=None)
@given(prompts, st.integers(1, 4))
def test_slab_release_accounting(prompt_list, ps):
    """Insert a slab per prompt (replacements included); after clear()
    every slab page must be back in the pool — the §11 payload-release
    hook cannot leak or double-free."""
    pool = PagePool(512, ps)
    cache = PrefixCache()
    for toks in prompt_list:
        full = shareable_pages(len(toks), ps)
        if full == 0:
            continue
        slab = PagedSlab(pool, pool.alloc(full))
        pool.release(slab.pages)        # slab now holds the only ref
        cache.insert(tuple(toks[:full * ps]), payload=slab,
                     payload_bytes=slab.payload_bytes)
    assert pool.pages_in_use == sum(
        len(n.payload.pages) for n in _nodes(cache) if n.payload)
    cache.clear()
    assert pool.pages_in_use == 0


def _nodes(cache):
    out, stack = [], list(cache.root.children.values())
    while stack:
        n = stack.pop()
        out.append(n)
        stack.extend(n.children.values())
    return out


@settings(max_examples=40, deadline=None)
@given(prompts, st.integers(1, 4), st.integers(0, 200))
def test_slab_eviction_under_budget(prompt_list, ps, budget_pages):
    """LRU leaf eviction under a byte budget releases exactly the
    dropped slabs' pages."""
    pool = PagePool(1024, ps, page_bytes=8.0)
    cache = PrefixCache(capacity_bytes=budget_pages * 8.0)
    for toks in prompt_list:
        full = shareable_pages(len(toks), ps)
        if full == 0:
            continue
        slab = PagedSlab(pool, pool.alloc(full))
        pool.release(slab.pages)
        if not cache.insert(tuple(toks[:full * ps]), payload=slab,
                            payload_bytes=slab.payload_bytes):
            # over-budget insert may have been refused outright; our
            # slab is attached only if the node reports it
            if not any(n.payload is slab for n in _nodes(cache)):
                slab.release()
    live = sum(len(n.payload.pages) for n in _nodes(cache) if n.payload)
    assert pool.pages_in_use == live
    assert cache.used_bytes <= cache.capacity_bytes or live == 0
    cache.clear()
    assert pool.pages_in_use == 0


def test_slab_release_is_idempotent():
    pool = PagePool(8, 4)
    slab = PagedSlab(pool, pool.alloc(3))
    pool.release(slab.pages)
    slab.release()
    slab.release()                        # second call is a no-op
    assert pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# Dtype-aware pool accounting (DESIGN.md §16)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 48), st.integers(1, 64), st.integers(1, 8),
       st.integers(1, 64), st.sampled_from([None, "int8"]))
def test_pool_dtype_is_accounting_metadata_only(num_pages, page_size,
                                                n_alloc, kv_heads, dtype):
    """A quantized-resident pool allocates exactly like a bf16 one —
    the dtype rides along as metadata, and ``page_bytes`` (payload +
    fp32 scale sidecar for int8) is what every byte consumer sees."""
    payload = page_size * kv_heads * (1.0 if dtype == "int8" else 2.0)
    sidecar = kv_heads * 4.0 if dtype == "int8" else 0.0
    pool = PagePool(num_pages, page_size, page_bytes=payload + sidecar,
                    dtype=dtype)
    assert pool.dtype == dtype
    assert pool.page_bytes == payload + sidecar
    n = min(n_alloc, pool.free_pages)
    if n == 0:
        return
    slab = PagedSlab(pool, pool.alloc(n))
    # slab byte accounting charges the sidecar alongside the payload
    assert slab.payload_bytes == pytest.approx(n * (payload + sidecar))
    if dtype == "int8":
        assert slab.payload_bytes > n * payload
    assert pool.free_pages + pool.pages_in_use == pool.num_allocatable
    pool.release(slab.pages)
    assert pool.pages_in_use == 0
