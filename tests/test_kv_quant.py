"""Pallas int8 KV-quantization kernels (DESIGN.md §10): interpret-mode
kernels vs the jnp oracles, round-trip error bounds, zero-padding
exactness, and the wire-ratio arithmetic the codec/scheduler share."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import kv_quant

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("shape", [(4, 32), (2, 3, 5, 2, 32), (65, 16),
                                   (1, 128), (300, 64)])
def test_quantize_matches_ref(shape):
    x = jnp.asarray(RNG.normal(size=shape).astype(np.float32))
    q, s = kv_quant.quantize_int8(x)
    qr, sr = kv_quant.quantize_int8_ref(x)
    assert q.shape == x.shape and q.dtype == jnp.int8
    assert s.shape == x.shape[:-1] + (1,) and s.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    # scales agree up to XLA fusion/reassociation rounding
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_roundtrip_error_bounded_by_half_scale(dtype):
    x = jnp.asarray(RNG.normal(size=(6, 4, 32)).astype(np.float32)).astype(dtype)
    q, s = kv_quant.quantize_int8(x)
    back = kv_quant.dequantize_int8(q, s, dtype)
    assert back.dtype == dtype
    err = np.abs(np.asarray(back, np.float32) - np.asarray(x, np.float32))
    # symmetric round-to-nearest: elementwise error ≤ scale/2 (plus the
    # target dtype's own rounding for bf16)
    bound = np.asarray(s) / 2.0 + (0.0 if dtype == jnp.float32 else 0.02)
    assert np.all(err <= bound + 1e-7)


def test_zero_rows_roundtrip_exact():
    """pad_capacity zero-padding must survive the codec bit-identically."""
    x = jnp.zeros((8, 64), jnp.float32)
    q, s = kv_quant.quantize_int8(x)
    assert not np.any(np.asarray(q))
    np.testing.assert_array_equal(
        np.asarray(kv_quant.dequantize_int8(q, s)), np.zeros((8, 64)))


def test_mixed_zero_and_signal_rows():
    x = np.zeros((4, 32), np.float32)
    x[1] = RNG.normal(size=32)
    q, s = kv_quant.quantize_int8(jnp.asarray(x))
    back = np.asarray(kv_quant.dequantize_int8(q, s))
    assert not back[0].any() and not back[2:].any()
    assert np.max(np.abs(back[1] - x[1])) <= float(np.asarray(s)[1, 0]) / 2 + 1e-7


def test_blockwise_matches_ref_and_roundtrips():
    x = jnp.asarray(RNG.normal(size=(65, 16)).astype(np.float32))
    q, s = kv_quant.quantize_int8_blockwise(x, block_rows=32)
    qr, sr = kv_quant.quantize_int8_blockwise_ref(
        jnp.pad(x, ((0, 31), (0, 0))), 32)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr)[:65])
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    assert s.shape == (3, 1)          # ceil(65/32) row blocks
    back = kv_quant.dequantize_int8_blockwise(q, s, block_rows=32)
    assert back.shape == x.shape
    # one scale per 32x16 tile: error bounded by that tile's scale/2
    err = np.abs(np.asarray(back) - np.asarray(x))
    per_row_bound = np.repeat(np.asarray(s), 32, axis=0)[:65] / 2.0
    assert np.all(err <= per_row_bound + 1e-7)


def test_wire_ratio_arithmetic():
    # fp32 at head_dim 32: 4 bytes -> 1 + 4/32 bytes
    assert kv_quant.compression_ratio(4, 32) == pytest.approx(4 / (1 + 4 / 32))
    # bf16 at head_dim 128
    assert kv_quant.compression_ratio(2, 128) == pytest.approx(2 / (1 + 4 / 128))
    # int8 source: never "compress" into more bytes
    assert kv_quant.compression_ratio(1, 64) == 1.0
