"""Request-lifecycle API (DESIGN.md §8): state-transition invariants,
streaming callbacks, legacy-wrapper equivalence, and runtime/simulator
metrics-schema parity."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import HPHD, LLAMA2_70B, schedule
from repro.core.cluster import heterogeneous_setting_1
from repro.core.scheduler import WorkloadMonitor
from repro.core.cost_model import WORKLOADS
from repro.models import init_params
from repro.serving import (Coordinator, IllegalTransition, METRIC_FIELDS,
                           Request, RequestState, ServeMetrics, ServeRequest,
                           offline_workload, simulate)

KEY = jax.random.PRNGKey(11)


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    return cfg, init_params(KEY, cfg)


@pytest.fixture(scope="module")
def placed():
    cl = heterogeneous_setting_1()
    res = schedule(cl, LLAMA2_70B, HPHD, max_refine_iters=4)
    return cl, res.placement


# ---------------------------------------------------------------------------
# state machine
# ---------------------------------------------------------------------------


def test_legal_lifecycle_stamps_timestamps():
    r = Request(rid=0, s_in=8, s_out=4, arrival=1.0)
    r.advance(RequestState.PREFILLING, 2.0)
    r.advance(RequestState.KV_TRANSFER, 3.0)
    r.advance(RequestState.DECODING, 4.0)
    r.advance(RequestState.DONE, 5.0)
    assert (r.prefill_start, r.prefill_end, r.transfer_end, r.decode_end) \
        == (2.0, 3.0, 4.0, 5.0)
    assert r.ttft == 2.0 and r.latency == 4.0
    assert r.tpot == pytest.approx(2.0 / 3)


@pytest.mark.parametrize("bad", [RequestState.DECODING, RequestState.DONE,
                                 RequestState.KV_TRANSFER])
def test_no_decoding_before_kv_transfer(bad):
    """A queued request can never jump ahead in the pipeline."""
    r = Request(rid=0, s_in=8, s_out=4, arrival=0.0)
    with pytest.raises(IllegalTransition):
        r.advance(bad, 1.0)


def test_no_decode_straight_from_prefill():
    r = Request(rid=0, s_in=8, s_out=4, arrival=0.0)
    r.advance(RequestState.PREFILLING, 1.0)
    with pytest.raises(IllegalTransition):
        r.advance(RequestState.DECODING, 2.0)


def test_single_token_shortcut_and_restart():
    r = Request(rid=0, s_in=8, s_out=1, arrival=0.0)
    r.advance(RequestState.PREFILLING, 1.0)
    r.advance(RequestState.DONE, 2.0)       # first token IS the output
    assert r.ttft == 2.0 and r.latency == 2.0 and r.tpot == 0.0
    with pytest.raises(IllegalTransition):
        r.restart()
    r2 = Request(rid=1, s_in=8, s_out=4, arrival=0.0)
    r2.advance(RequestState.PREFILLING, 1.0)
    r2.restart()                            # reschedule requeues it
    assert r2.phase is RequestState.QUEUED and r2.prefill_start is None


def test_simulator_drives_lifecycle(placed):
    cl, placement = placed
    reqs = offline_workload("HPHD", 40, seed=1)
    sim = simulate(cl, LLAMA2_70B, placement, reqs)
    for r in sim.requests:
        assert r.phase is RequestState.DONE
        assert r.arrival <= r.prefill_start <= r.prefill_end \
            <= r.transfer_end <= r.decode_end
        assert r.ttft is not None and r.tpot is not None


# ---------------------------------------------------------------------------
# runtime session: streaming, poll, legacy wrapper
# ---------------------------------------------------------------------------


def _reqs(cfg, n, lens=(5, 4, 6, 5, 3), max_new=4, seed=5):
    rng = np.random.default_rng(seed)
    return [ServeRequest(i, rng.integers(0, cfg.vocab, lens[i % len(lens)])
                         .astype(np.int32), max_new) for i in range(n)]


def test_streaming_matches_results_and_poll(small_model):
    cfg, params = small_model
    coord = Coordinator(cfg, params, num_decode_engines=2,
                        slots_per_engine=2, capacity=32)
    sess = coord.session()
    streamed = {}
    seen_states = set()
    for r in _reqs(cfg, 5):
        sess.submit(r, on_token=lambda rid, t, f:
                    streamed.setdefault(rid, []).append(t))
    while sess.unfinished:
        sess.step()
        for rid in streamed:
            st = sess.poll(rid)
            seen_states.add(st.state)
            assert st.tokens == streamed[rid]    # poll == stream so far
    for out in sess.results():
        assert out.tokens == streamed[out.rid]   # ordering preserved
        assert out.lifecycle.phase is RequestState.DONE
    assert RequestState.DONE in seen_states


def test_legacy_serve_equals_session(small_model):
    """The blocking wrapper must be byte-for-byte the session output."""
    cfg, params = small_model
    mk = lambda: Coordinator(cfg, params, num_decode_engines=2,
                             slots_per_engine=2, capacity=32)
    reqs = _reqs(cfg, 5)
    legacy = mk().serve([ServeRequest(r.rid, r.prompt, r.max_new_tokens)
                         for r in reqs])
    sess = mk().session()
    for r in reqs:
        sess.submit(r)
    session_out = sess.run().results()
    assert [o.tokens for o in legacy] == [o.tokens for o in session_out]


def test_sessions_are_exclusive_while_in_flight(small_model):
    """Decode slots and routing counters are shared: a second session
    over the same engines must be refused until the first drains."""
    cfg, params = small_model
    coord = Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=2, capacity=32)
    sess = coord.session()
    for r in _reqs(cfg, 2):
        sess.submit(r)
    sess.step()
    with pytest.raises(RuntimeError, match="active session"):
        coord.session()
    sess.run()
    assert coord.session() is not sess    # drained: reopening is fine


def test_prefill_backlog_bounded_by_slots(small_model):
    """Prefill must not run unboundedly ahead of decode admission —
    each handoff entry pins a full-capacity KV cache."""
    cfg, params = small_model
    coord = Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=2, capacity=32)
    sess = coord.session(max_prefill_batch=4)
    for r in _reqs(cfg, 10):
        sess.submit(r)
    while sess.unfinished:
        sess.step()
        assert len(sess._handoff) <= 2    # total slot count
    assert all(len(o.tokens) == 4 for o in sess.results())


def test_single_token_requests_runtime(small_model):
    cfg, params = small_model
    coord = Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=2, capacity=32)
    outs = coord.serve(_reqs(cfg, 3, max_new=1))
    assert all(len(o.tokens) == 1 for o in outs)
    assert all(o.lifecycle.phase is RequestState.DONE for o in outs)


def test_prefill_batch_matches_exact_shapes(small_model):
    """Bucketed/padded batched prefill must reproduce exact-shape
    prefill: same first token, same KV at true positions."""
    cfg, params = small_model
    from repro.serving.engine import PrefillEngine
    eng = PrefillEngine(cfg, params, cache_capacity=32)
    assert eng.supports_padding
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 3, 7)]
    batched = eng.prefill_batch(prompts)
    for p, (tok, cache) in zip(prompts, batched):
        ref_tok, ref_cache = eng.prefill(p[None])
        assert tok == int(ref_tok[0])
        k_b = np.asarray(jax.tree.leaves(cache)[0], np.float32)
        k_r = np.asarray(jax.tree.leaves(ref_cache)[0], np.float32)
        assert np.array_equal(k_b[:, :, :len(p)], k_r[:, :, :len(p)])


# ---------------------------------------------------------------------------
# shared metrics schema: runtime == simulator
# ---------------------------------------------------------------------------


def test_metrics_schema_parity(small_model, placed):
    cfg, params = small_model
    coord = Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=2, capacity=32)
    sess = coord.session()
    for r in _reqs(cfg, 3):
        sess.submit(r)
    runtime = sess.run().metrics()

    cl, placement = placed
    sim = simulate(cl, LLAMA2_70B, placement,
                   offline_workload("HPHD", 20, seed=2))

    assert isinstance(sim, ServeMetrics)          # one schema, two domains
    for field in METRIC_FIELDS:
        assert hasattr(runtime, field), f"runtime missing {field}"
        assert hasattr(sim, field), f"simulator missing {field}"
    # identical summary keys, all finite on completed runs
    rs, ss = runtime.summary(), sim.summary()
    assert set(rs) == set(ss)
    for k, v in {**rs, **ss}.items():
        assert np.isfinite(v), k
    # both sides measure with the same lifecycle Request type
    assert {type(r) for r in runtime.requests} \
        == {type(r) for r in sim.requests} == {Request}


def test_monitor_consumes_lifecycle_requests():
    mon = WorkloadMonitor(WORKLOADS["HPLD"], window=8, min_observations=2)
    mon.observe(Request(rid=0, s_in=100, s_out=200, arrival=0.0))
    mon.observe(700, 300)                     # raw counts still accepted
    assert mon.n == 2
    snap = mon.snapshot()
    assert snap.s_in == 400 and snap.s_out == 250
