"""Prefill/decode consistency: stepping the decode path token by token
must reproduce the prefill path's logits (teacher forcing) — this is the
correctness contract the disaggregated KV handoff relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import decode_step, init_params, prefill

KEY = jax.random.PRNGKey(7)

# one representative per cache mechanism
CASES = ["qwen3-1.7b",            # dense GQA + qk_norm (plain KV cache)
         "jamba-v0.1-52b",        # hybrid mamba/attn/moe (mixed cache)
         "xlstm-125m",            # mLSTM/sLSTM recurrent state
         "whisper-large-v3",      # enc-dec (self + cross cache)
         "llama-3.2-vision-90b"]  # cross-attn image layers


def _extra(cfg, b, key):
    extra = {}
    if cfg.is_encdec:
        extra["encoder_frames"] = jax.random.normal(
            key, (b, cfg.encoder_frames, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.num_image_tokens:
        extra["image_embeds"] = jax.random.normal(
            key, (b, cfg.num_image_tokens, cfg.d_model)).astype(jnp.bfloat16)
    return extra


@pytest.mark.parametrize("name", CASES)
def test_decode_matches_prefill(name):
    import dataclasses
    from repro.configs.base import BlockSpec
    cfg = ARCHS[name].reduced()
    if cfg.num_experts:
        # swap MoE FFNs for dense MLPs: near-tie router flips under bf16
        # make strict logit equality ill-posed for MoE (expert choice is
        # discontinuous); MoE math itself is covered by test_moe.py.
        # This test targets the CACHE mechanics (mamba+attn hybrid here).
        period = tuple(dataclasses.replace(bs, ffn="mlp")
                       if bs.ffn == "moe" else bs for bs in cfg.period)
        cfg = dataclasses.replace(cfg, period=period, num_experts=0,
                                  top_k=0, d_ff=cfg.d_ff or 128)
    params = init_params(KEY, cfg)
    b, s, n_step = 2, 6, 4
    total = s + n_step
    toks = jax.random.randint(KEY, (b, total), 0, cfg.vocab)
    extra = _extra(cfg, b, KEY)

    # ground truth: prefill over progressively longer prefixes
    want = []
    for t in range(s, total):
        lg, _ = prefill(params, cfg, toks[:, :t + 1],
                        cache_capacity=total + 1, **extra)
        want.append(np.asarray(lg, np.float32))

    # decode path: prefill s tokens then teacher-force the rest
    lg, cache = prefill(params, cfg, toks[:, :s], cache_capacity=total + 1,
                        **extra)
    got = []
    for t in range(s, total):
        pos = jnp.full((b, 1), t, jnp.int32)
        lg, cache = decode_step(params, cfg, cache, toks[:, t:t + 1], pos)
        got.append(np.asarray(lg, np.float32))

    for t, (w, g) in enumerate(zip(want, got)):
        # bf16 params + fp32 softmax: loose numeric tol, tie-aware argmax
        np.testing.assert_allclose(g, w, atol=0.15, rtol=0.1,
                                   err_msg=f"{name} step {t}")
        _assert_argmax_matches(g, w, f"{name} argmax@{t}")


def _assert_argmax_matches(g, w, msg, tie_tol=0.1):
    """Exact argmax equality, except when the reference logits are tied
    at bf16 granularity: the reduced jamba config produces reference
    top-2 gaps as small as 0.0, where argmax is ill-posed and the two
    paths may legitimately pick either side. The decode path's pick
    must then still score within ``tie_tol`` of the reference max."""
    ga, wa = g.argmax(-1), w.argmax(-1)
    for row in np.argwhere(ga != wa)[:, 0]:
        assert w[row, ga[row]] >= w[row, wa[row]] - tie_tol, \
            f"{msg} row {row}: picked logit {w[row, ga[row]]:.4f} " \
            f"vs max {w[row, wa[row]]:.4f}"


def test_sliding_window_decode_matches_prefill():
    cfg = ARCHS["qwen3-1.7b"].with_sliding_window(8).reduced()
    assert cfg.sliding_window == 8
    params = init_params(KEY, cfg)
    b, s, n_step = 1, 12, 3   # prompt longer than the window
    total = s + n_step
    toks = jax.random.randint(KEY, (b, total), 0, cfg.vocab)

    want = []
    for t in range(s, total):
        lg, _ = prefill(params, cfg, toks[:, :t + 1], cache_capacity=total)
        want.append(np.asarray(lg, np.float32))

    lg, cache = prefill(params, cfg, toks[:, :s], cache_capacity=total)
    got = []
    for t in range(s, total):
        pos = jnp.full((b, 1), t, jnp.int32)
        lg, cache = decode_step(params, cfg, cache, toks[:, t:t + 1], pos)
        got.append(np.asarray(lg, np.float32))

    for t, (w, g) in enumerate(zip(want, got)):
        np.testing.assert_allclose(g, w, atol=0.15, rtol=0.1,
                                   err_msg=f"swa step {t}")
        assert (g.argmax(-1) == w.argmax(-1)).all()
