"""Roofline report arithmetic and dry-run report integrity."""
import json
import os

import pytest

from repro.roofline import RooflineReport, hw

REPORT = os.path.join(os.path.dirname(__file__), "..", "reports",
                      "dryrun_report.json")


def _rep(**kw):
    base = dict(arch="a", shape="s", mesh="16x16", chips=256,
                hlo_flops=197e12, hlo_bytes=819e9, coll_bytes=50e9,
                coll_breakdown={}, model_flops=197e12 * 256)
    base.update(kw)
    return RooflineReport(**base)


def test_terms_unit_consistency():
    r = _rep()
    assert r.t_compute == pytest.approx(1.0)     # one second of peak compute
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(1.0)
    # model_flops = hlo_flops × chips ⇒ all compiled compute is useful
    assert r.useful_flops_ratio == pytest.approx(1.0)


def test_bottleneck_selection():
    assert _rep(hlo_bytes=819e9 * 10).bottleneck == "memory"
    assert _rep(coll_bytes=50e9 * 10).bottleneck == "collective"
    assert _rep(hlo_flops=197e12 * 10).bottleneck == "compute"


@pytest.mark.skipif(not os.path.exists(REPORT),
                    reason="dry-run report not generated yet")
def test_dryrun_report_complete_and_green():
    with open(REPORT) as f:
        records = json.load(f)
    ok = [r for r in records if r.get("status") == "ok"]
    assert len(ok) == 80, f"expected 80 ok records, got {len(ok)}"
    combos = {(r["arch"], r["shape"], r["mesh"]) for r in ok}
    assert len(combos) == 80
    for r in ok:
        assert r["t_compute_s"] >= 0
        assert r["t_memory_s"] > 0
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert 0 <= r["useful_flops_ratio"] <= 1.5
