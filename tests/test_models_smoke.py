"""Per-architecture smoke tests: REDUCED variant (≤2 periods, d_model≤256,
≤4 experts) runs one forward/decode/train step on CPU; output shapes and
no-NaN asserted. Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED
from repro.models import (count_params, decode_step, init_cache, init_params,
                          prefill, train_forward)

KEY = jax.random.PRNGKey(0)


def _extra(cfg, b):
    extra = {}
    if cfg.is_encdec:
        extra["encoder_frames"] = jnp.ones(
            (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    if cfg.num_image_tokens:
        extra["image_embeds"] = jnp.ones(
            (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    return extra


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = ARCHS[name].reduced()
            cache[name] = (cfg, init_params(KEY, cfg))
        return cache[name]

    return get


@pytest.mark.parametrize("name", ASSIGNED)
def test_prefill_and_decode_smoke(name, models):
    cfg, params = models(name)
    b, s = 2, 8
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    logits, cache = prefill(params, cfg, toks, cache_capacity=s + 4,
                            **_extra(cfg, b))
    assert logits.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    pos = jnp.full((b, 1), s, jnp.int32)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    lg2, cache2 = decode_step(params, cfg, cache, tok, pos)
    assert lg2.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_step_smoke(name, models):
    cfg, params = models(name)
    b, s = 2, 8
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    labels = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    loss = train_forward(params, cfg, toks, labels, **_extra(cfg, b))
    assert np.isfinite(float(loss))
    grads = jax.grad(
        lambda p: train_forward(p, cfg, toks, labels, **_extra(cfg, b))
    )(params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ASSIGNED)
def test_full_config_param_counts(name):
    """Full (non-reduced) configs land near their nameplate sizes."""
    expected = {
        "xlstm-125m": (0.1e9, 0.3e9),
        "yi-34b": (30e9, 40e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
        "llama-3.2-vision-90b": (80e9, 95e9),
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "jamba-v0.1-52b": (45e9, 58e9),
        "nemotron-4-15b": (13e9, 18e9),
        "qwen2.5-32b": (29e9, 36e9),
        "llama4-maverick-400b-a17b": (360e9, 440e9),
        "qwen3-moe-30b-a3b": (26e9, 34e9),
    }
    lo, hi = expected[name]
    n = count_params(ARCHS[name])
    assert lo <= n <= hi, f"{name}: {n/1e9:.1f}B not in [{lo/1e9},{hi/1e9}]"


def test_sliding_window_variant_structure():
    cfg = ARCHS["yi-34b"].with_sliding_window(64)
    assert all(b.mixer == "swa" for b in cfg.period)
    assert cfg.sliding_window == 64
    r = cfg.reduced()
    cache = init_cache(r, batch=2, capacity=128)
    # swa cache is window-sized, not capacity-sized
    assert cache[0]["k"].shape[2] == r.sliding_window
