"""MoE dispatch: sort/scatter capacity routing vs a dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.models import common, moe

KEY = jax.random.PRNGKey(3)


def _dense_reference(params, x, top_k):
    """y = Σ_topk gate_e · FFN_e(x), computed per token with no capacity."""
    b, s, d = x.shape
    e = params["router"].shape[-1]
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)
    # all experts on all tokens (reference only — O(E) compute)
    g = jnp.einsum("td,edf->tef", xt, params["w_gate"])
    u = jnp.einsum("td,edf->tef", xt, params["w_up"])
    h = common.swiglu(g, u)
    y_all = jnp.einsum("tef,efd->ted", h, params["w_down"])  # [T,E,D]
    out = jnp.zeros_like(xt)
    for k in range(top_k):
        sel = jnp.take_along_axis(
            y_all, expert_ids[:, k][:, None, None].repeat(d, -1), axis=1
        )[:, 0]
        out = out + sel * gate_vals[:, k][:, None].astype(sel.dtype)
    if "shared" in params:
        sh = params["shared"]
        out = out + common.swiglu(xt @ sh["w_gate"], xt @ sh["w_up"]) \
            @ sh["w_down"]
    return out.reshape(b, s, d)


@pytest.mark.parametrize("e,k,shared", [(4, 1, False), (4, 2, False),
                                        (8, 2, True), (8, 4, False)])
def test_moe_matches_dense_reference(e, k, shared):
    d, f = 16, 32
    params = moe.init_moe(KEY, d, f, e, "swiglu", shared,
                          dtype=jnp.float32)
    x = jax.random.normal(KEY, (2, 6, d), jnp.float32)
    # capacity large enough that nothing drops
    y, aux = moe.apply_moe(params, x, k, capacity_factor=float(e))
    want = _dense_reference(params, x, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens_not_corrupts():
    """With a tiny capacity, outputs are a (gated) subset — never NaN and
    never mixing tokens."""
    d, f, e, k = 8, 16, 4, 2
    params = moe.init_moe(KEY, d, f, e, "swiglu", False, dtype=jnp.float32)
    x = jax.random.normal(KEY, (1, 32, d), jnp.float32)
    y, _ = moe.apply_moe(params, x, k, capacity_factor=0.1)
    assert np.isfinite(np.asarray(y)).all()
    # zero rows allowed (dropped), but non-zero rows must match the
    # no-drop result for the experts that served them
    y_full, _ = moe.apply_moe(params, x, k, capacity_factor=float(e))
    yf = np.asarray(y_full)[0]
    ys = np.asarray(y)[0]
    fully_served = sum(bool(np.allclose(ys[t], yf[t], atol=1e-5))
                       for t in range(32))
    affected = sum(bool(not np.allclose(ys[t], yf[t], atol=1e-5))
                   for t in range(32))
    # tiny capacity must drop someone, but early-slot tokens stay exact
    assert affected > 0
    assert fully_served > 0


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(0, 1000))
def test_moe_aux_loss_finite_and_positive(k, seed):
    d, f, e = 8, 16, 8
    key = jax.random.PRNGKey(seed)
    params = moe.init_moe(key, d, f, e, "swiglu", False, dtype=jnp.float32)
    x = jax.random.normal(key, (1, 16, d), jnp.float32)
    y, aux = moe.apply_moe(params, x, k)
    assert np.isfinite(float(aux)) and float(aux) > 0
    assert np.isfinite(np.asarray(y)).all()


def test_moe_grads_flow_to_router_and_experts():
    d, f, e, k = 8, 16, 4, 2
    params = moe.init_moe(KEY, d, f, e, "swiglu", False, dtype=jnp.float32)
    x = jax.random.normal(KEY, (1, 8, d), jnp.float32)

    def loss(p):
        y, aux = moe.apply_moe(p, x, k)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w_gate"]).sum()) > 0
    assert float(jnp.abs(g["w_down"]).sum()) > 0


def test_grouped_moe_matches_ungrouped():
    """apply_moe_grouped (the §Perf dispatch) must agree with apply_moe
    when capacity is generous (per-group routing is a partition of the
    same token set)."""
    d, f, e, k = 16, 32, 4, 2
    params = moe.init_moe(KEY, d, f, e, "swiglu", False, dtype=jnp.float32)
    x = jax.random.normal(KEY, (4, 8, d), jnp.float32)
    y1, _ = moe.apply_moe(params, x, k, capacity_factor=float(e))
    y2, _ = moe.apply_moe_grouped(params, x, k, capacity_factor=float(e),
                                  groups=4, constrain=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)


def test_grouped_moe_in_model_forward():
    """End-to-end: a reduced MoE arch with moe_groups>1 runs prefill +
    decode and matches the ungrouped model closely (same routing when
    capacity is generous)."""
    import dataclasses
    from repro.configs import ARCHS
    from repro.models import init_params, prefill

    base = dataclasses.replace(ARCHS["qwen3-moe-30b-a3b"].reduced(),
                               moe_capacity_factor=4.0)
    grouped = dataclasses.replace(base, moe_groups=2)
    params = init_params(KEY, base)
    toks = jax.random.randint(KEY, (2, 8), 0, base.vocab)
    l1, _ = prefill(params, base, toks, cache_capacity=12)
    l2, _ = prefill(params, grouped, toks, cache_capacity=12)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               atol=0.2, rtol=0.1)
    assert (np.asarray(l1).argmax(-1) == np.asarray(l2).argmax(-1)).all()
