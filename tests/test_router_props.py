"""Property tests for the §12 router tier (hypothesis): admission-queue
conservation and ordering laws, the aging bound, fleet-level request
conservation under failures, and METRIC_FIELDS schema parity."""
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import numpy as np  # noqa: E402

from repro.serving import (AdmissionQueue, AdmissionRejected,  # noqa: E402
                           METRIC_FIELDS, Request, RequestState,
                           mixed_priority_workload, simulate_fleet)
from repro.serving.metrics import ServeMetrics  # noqa: E402
from repro.serving.router import _QEntry  # noqa: E402


def _qe(rid, priority, seq, step=0):
    return _QEntry(Request(rid=rid, s_in=1, s_out=1, arrival=0.0,
                           priority=priority), seq, step)


# ---------------------------------------------------------------------------
# Queue-level laws
# ---------------------------------------------------------------------------


ops = st.lists(
    st.one_of(
        st.tuples(st.just("push"), st.integers(0, 3)),     # priority
        st.tuples(st.just("pop"), st.integers(0, 100)),    # step
        st.tuples(st.just("remove"), st.integers(0, 40)),  # rid
    ),
    max_size=60)


@settings(max_examples=80, deadline=None)
@given(st.integers(1, 12), st.integers(1, 16), ops)
def test_queue_conservation_under_random_ops(capacity, age_every, script):
    """pushed == popped + removed + len(queue); overflow is the typed
    error and never mutates the queue."""
    q = AdmissionQueue(capacity=capacity, age_every=age_every)
    pushed = popped = removed = 0
    for op, arg in script:
        if op == "push":
            before = len(q)
            try:
                q.push(_qe(pushed, arg, pushed))
                pushed += 1
            except AdmissionRejected:
                assert before == capacity == len(q)
        elif op == "pop":
            if len(q):
                q.pop(arg)
                popped += 1
        else:
            if q.remove(arg) is not None:
                removed += 1
    assert pushed == popped + removed + len(q)
    assert set(q.rids()) <= set(range(pushed))


@settings(max_examples=80, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=1, max_size=20))
def test_queue_fifo_within_class_without_aging(priorities):
    """With aging off, pops are strict priority order between classes
    and seq (FIFO) order within a class."""
    q = AdmissionQueue(capacity=len(priorities), age_every=10 ** 9)
    for seq, p in enumerate(priorities):
        q.push(_qe(seq, p, seq))
    out = [q.pop(0) for _ in range(len(priorities))]
    keys = [(e.life.priority, e.seq) for e in out]
    assert keys == sorted(keys)


@settings(max_examples=80, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=2, max_size=16),
       st.integers(1, 10),
       st.lists(st.integers(0, 60), min_size=1, max_size=16))
def test_queue_aging_bound(priorities, age_every, steps):
    """The §12 starvation bound: whenever an entry of class p pops
    while one of class q < p still waits, the popped one has waited at
    least ``age_every * (p - q)`` steps."""
    q = AdmissionQueue(capacity=len(priorities), age_every=age_every)
    for seq, p in enumerate(priorities):
        q.push(_qe(seq, p, seq, step=0))
    for s in sorted(steps):
        if not len(q):
            break
        e = q.pop(s)
        waited = s - e.enqueue_step
        for rid in q.rids():
            other = next(x for x in q._entries if x.life.rid == rid)
            if other.life.priority < e.life.priority:
                assert waited >= age_every * (e.life.priority
                                              - other.life.priority)


# ---------------------------------------------------------------------------
# Fleet-level laws (scheduling domain — pure python, fast)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 20), st.integers(0, 5), st.integers(2, 64),
       st.booleans())
def test_fleet_conservation_and_completion(n, seed, queue_capacity, kill):
    """admitted + rejected + cancelled == submitted, on any trace,
    with or without a replica failure; every admitted request ends
    DONE with its full token budget."""
    failures = {1: 1} if kill else None
    res = simulate_fleet(
        mixed_priority_workload(n=n, rate_rps=80.0, seed=seed),
        num_replicas=2, slots_per_replica=1, max_prefill_batch=1,
        capacity=256, queue_capacity=queue_capacity, failures=failures)
    c = res.counters
    assert c["admitted"] + c["rejected"] + c["cancelled"] == n
    assert c["cancelled"] == 0
    done = [r for r in res.requests if r.phase is RequestState.DONE]
    assert len(done) == c["admitted"]
    for r in done:
        assert r.tokens_out == r.s_out
    for r in res.requests:
        if r.phase is RequestState.REJECTED:
            assert r.latency is None


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(2, 12))
def test_fleet_dispatch_log_fifo_within_class(age_every, n):
    """First dispatches within one priority class leave the queue in
    admission order, whatever the aging rate (aging reorders BETWEEN
    classes only)."""
    res = simulate_fleet(
        mixed_priority_workload(n=n, rate_rps=200.0, seed=2),
        num_replicas=2, slots_per_replica=1, max_prefill_batch=1,
        capacity=256, age_every=age_every)
    by_class = {}
    for row in res.dispatch_log:
        if row["redispatch"]:
            continue
        by_class.setdefault(row["priority"], []).append(row["rid"])
    for rids in by_class.values():
        assert rids == sorted(rids)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 4))
def test_metric_fields_schema_parity(seed):
    """Every METRIC_FIELDS name resolves on both result types, the
    by-class fields are dicts keyed by the trace's priority classes,
    and summary() stays finite-scalar-only."""
    reqs = mixed_priority_workload(n=8, rate_rps=100.0, seed=seed)
    res = simulate_fleet(reqs, num_replicas=2, slots_per_replica=2,
                         max_prefill_batch=2, capacity=256)
    bare = ServeMetrics(requests=list(res.requests),
                        makespan=res.makespan,
                        decode_tokens=res.decode_tokens)
    classes = {r.priority for r in reqs}
    for obj in (res, bare):
        for f in METRIC_FIELDS:
            assert hasattr(obj, f), f
        assert set(obj.avg_ttft_by_class) <= classes
        assert set(obj.slo_attainment_by_class) <= classes
        assert set(obj.cache_hit_rate_by_class) <= classes
        s = obj.summary()
        assert all(isinstance(v, float) and np.isfinite(v)
                   for v in s.values())
    assert res.summary() == bare.summary()
