"""Prefix-cache subsystem (DESIGN.md §9): radix-tree unit invariants,
cache-aware simulator behaviour, and runtime suffix-prefill
bit-identity. Property-based radix tests live in
tests/test_prefix_cache_props.py (optional hypothesis dep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import LLAMA2_70B, WORKLOADS, schedule
from repro.core.cluster import heterogeneous_setting_1
from repro.models import init_params, prefill
from repro.serving import (Coordinator, PrefixCache, ServeRequest, simulate)
from repro.serving.workload import multi_turn_workload, prefix_trace

KEY = jax.random.PRNGKey(21)


# ---------------------------------------------------------------------------
# radix tree
# ---------------------------------------------------------------------------


def test_insert_match_split():
    pc = PrefixCache()
    assert pc.insert([1, 2, 3]) == 3
    assert pc.insert([1, 2, 4, 5]) == 2          # shares [1,2], adds [4,5]
    assert pc.matched_len([1, 2, 3]) == 3
    assert pc.matched_len([1, 2, 4, 5, 6]) == 4
    assert pc.matched_len([1, 2, 9]) == 2        # stops at the split point
    assert pc.matched_len([7, 8]) == 0
    assert pc.insert([1, 2, 3]) == 0             # fully present
    assert pc.num_tokens == 5                    # shared prefix stored once


def test_match_payload_covers_prefix():
    pc = PrefixCache()
    pc.insert([1, 2, 3, 4], payload="slab-A", payload_bytes=10)
    m = pc.match([1, 2, 9])
    assert m.length == 2 and m.payload == "slab-A"   # superstring's slab
    pc.insert([1, 2, 3, 4, 5], payload="slab-B", payload_bytes=10)
    m = pc.match([1, 2, 3, 4, 5])
    assert m.length == 5 and m.payload in ("slab-A", "slab-B")


def test_lru_eviction_respects_budget_and_pins():
    pc = PrefixCache(capacity_bytes=8, bytes_per_token=1.0)
    pc.insert([1, 1, 1, 1])
    m = pc.match([1, 1, 1, 1], lock=True)            # pin the hot path
    pc.insert([2, 2, 2, 2])
    pc.insert([3, 3, 3, 3])                          # must evict [2,...]
    assert pc.used_bytes <= 8
    assert pc.matched_len([1, 1, 1, 1]) == 4         # pinned path survives
    assert pc.matched_len([2, 2, 2, 2]) == 0         # LRU victim
    assert pc.matched_len([3, 3, 3, 3]) == 4
    pc.unlock(m.node)
    # with the pin released the old path is evictable again
    pc.insert([4, 4, 4, 4, 4])
    assert pc.used_bytes <= 8


def test_pinned_never_dropped_under_full_pressure():
    pc = PrefixCache(capacity_bytes=6, bytes_per_token=1.0)
    pc.insert([5, 6, 7])
    m = pc.match([5, 6, 7], lock=True)
    # larger than the whole budget minus the pinned path: refused
    assert pc.insert([8] * 6) == 0
    assert pc.matched_len([5, 6, 7]) == 3
    assert pc.used_bytes <= 6
    pc.unlock(m.node)
    assert pc.insert([8] * 6) == 6                   # now it fits
    assert pc.matched_len([5, 6, 7]) == 0


def test_insert_never_orphans_its_own_extension_point():
    """Regression: extending a cached prompt under budget pressure must
    not let the LRU sweep evict the very chain being extended (which
    would attach the new leaf to a detached parent — unreachable
    tokens, permanently leaked bytes)."""
    pc = PrefixCache(capacity_bytes=1000, bytes_per_token=1.0)
    prompt = [1] * 400
    assert pc.insert(prompt) == 400
    # the multi-turn extension cannot fit alongside its own prefix:
    # the insert must be refused outright, never half-applied
    assert pc.insert(prompt + [2] * 700) == 0
    assert pc.matched_len(prompt) == 400           # prefix still reachable
    assert pc.used_bytes == pc.num_tokens == 400   # no orphaned bytes
    # an unrelated chain IS evictable to make room for an extension
    pc2 = PrefixCache(capacity_bytes=1000, bytes_per_token=1.0)
    pc2.insert([9] * 500)
    pc2.insert(prompt)
    assert pc2.insert(prompt + [2] * 300) == 300   # evicts the [9]-chain
    assert pc2.matched_len(prompt + [2] * 300) == 700
    assert pc2.matched_len([9] * 500) == 0
    assert pc2.used_bytes == pc2.num_tokens == 700


def test_refcounts_balanced_and_clear():
    pc = PrefixCache()
    pc.insert([1, 2, 3])
    pc.insert([1, 2, 4])
    handles = [pc.match([1, 2, 3], lock=True) for _ in range(3)]
    for h in handles:
        pc.unlock(h.node)

    def refs(node):
        yield node.refs
        for c in node.children.values():
            yield from refs(c)

    assert all(r == 0 for r in refs(pc.root))
    pc.clear()                                       # §7 swap invalidation
    assert pc.matched_len([1, 2, 3]) == 0 and pc.used_bytes == 0


def test_payload_bytes_accounting():
    pc = PrefixCache(capacity_bytes=100, bytes_per_token=1.0)
    pc.insert([1, 2], payload="a", payload_bytes=50)
    assert pc.used_bytes == 52
    pc.insert([1, 2], payload="b", payload_bytes=30)  # replace slab
    assert pc.used_bytes == 32
    pc.evict_tokens(2)
    assert pc.used_bytes == 0


def test_payload_replacement_charges_only_the_delta():
    """Regression: re-serving a cached prompt swaps its slab in place —
    only the byte delta may trigger eviction, never the full new slab
    size (which would evict bystander prefixes for a net-zero swap)."""
    pc = PrefixCache(capacity_bytes=100, bytes_per_token=1.0)
    pc.insert([1, 2], payload="a", payload_bytes=50)   # used 52
    pc.insert([3, 4], payload="c", payload_bytes=40)   # used 94
    pc.insert([1, 2], payload="b", payload_bytes=55)   # delta +5 → 99
    assert pc.used_bytes == 99
    assert pc.matched_len([3, 4]) == 2                 # bystander survives
    assert pc.match([1, 2]).payload == "b"


# ---------------------------------------------------------------------------
# scheduling-domain: cache-aware simulation
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def placed():
    cl = heterogeneous_setting_1()
    res = schedule(cl, LLAMA2_70B, WORKLOADS["LPLD"], max_refine_iters=2)
    return cl, res.placement


def test_sim_prefix_caching_beats_blind(placed):
    cl, placement = placed
    blind = simulate(cl, LLAMA2_70B, placement,
                     prefix_trace("multiturn", 60, 4.0, seed=5))
    aware = simulate(cl, LLAMA2_70B, placement,
                     prefix_trace("multiturn", 60, 4.0, seed=5),
                     prefix_caching=True)
    assert blind.cache_hit_rate == 0.0 and blind.reused_tokens == 0
    assert aware.cache_hit_rate > 0.2
    assert aware.prefill_tokens_computed < blind.prefill_tokens_computed
    assert aware.avg_ttft < blind.avg_ttft
    # same tokens decoded either way — reuse only skips prefill work
    assert aware.decode_tokens == blind.decode_tokens


def test_sim_cold_trace_unchanged_by_flag(placed):
    """Content-free requests (legacy traces) must simulate identically
    with the cache on: there is nothing to match."""
    from repro.serving import offline_workload
    cl, placement = placed
    a = simulate(cl, LLAMA2_70B, placement, offline_workload("LPLD", 30, 7))
    b = simulate(cl, LLAMA2_70B, placement, offline_workload("LPLD", 30, 7),
                 prefix_caching=True)
    assert a.avg_ttft == b.avg_ttft and a.makespan == b.makespan
    assert b.cache_hit_rate == 0.0


def test_multi_turn_trace_shapes():
    reqs = multi_turn_workload(4, 3, 2.0, seed=0)
    assert len(reqs) == 12
    for r in reqs:
        assert r.tokens is not None and len(r.tokens) == r.s_in
    by_conv = {}
    for r in sorted(reqs, key=lambda r: r.arrival):
        prev = by_conv.get(r.prefix_id)
        if prev is not None:
            # turn k's prompt extends turn k-1's full prompt
            assert r.shared_len >= len(prev)
            assert r.tokens[:len(prev)] == prev
        by_conv[r.prefix_id] = r.tokens


# ---------------------------------------------------------------------------
# runtime: suffix-only prefill bit-identity + served-output equivalence
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    return cfg, init_params(KEY, cfg)


def test_prefill_suffix_bit_identical(small_model):
    """Suffix-only prefill seeded from a cached slab must reproduce full
    prefill exactly: same logits, same KV at every prompt position
    (attention/norms/MLP are row-wise — DESIGN.md §9)."""
    cfg, params = small_model
    from repro.serving.engine import PrefillEngine
    eng = PrefillEngine(cfg, params, cache_capacity=32)
    assert eng.supports_prefix_reuse
    rng = np.random.default_rng(3)
    full = rng.integers(0, cfg.vocab, 14).astype(np.int32)
    for cut in (1, 7, 13):
        _, slab = prefill(params, cfg, jnp.asarray(full[:cut])[None],
                          cache_capacity=32)
        ref_logits, ref_cache = prefill(params, cfg, jnp.asarray(full)[None],
                                        cache_capacity=32)
        tok, cache = eng.prefill_suffix(full, cut, slab)
        assert tok == int(jnp.argmax(ref_logits, -1)[0])
        for a, b in zip(jax.tree.leaves(ref_cache), jax.tree.leaves(cache)):
            assert np.array_equal(np.asarray(a)[:, :, :len(full)],
                                  np.asarray(b)[:, :, :len(full)]), cut


def test_serve_with_prefix_cache_matches_cacheless(small_model):
    """End-to-end: a cache-aware coordinator must emit exactly the same
    tokens as a cache-blind one on a shared-prefix batch, while
    actually reusing prefixes."""
    cfg, params = small_model
    rng = np.random.default_rng(11)
    sysp = rng.integers(0, cfg.vocab, 8)
    prompts = [np.concatenate([sysp, rng.integers(0, cfg.vocab, 3 + i)])
               .astype(np.int32) for i in range(4)]
    reqs = lambda: [ServeRequest(i, p, 3) for i, p in enumerate(prompts)]

    blind = Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=2, capacity=32)
    ref = [o.tokens for o in blind.serve(reqs())]

    aware = Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=2, capacity=32,
                        num_prefill_engines=2,
                        prefix_cache_bytes=float("inf"))
    sess = aware.session(max_prefill_batch=1)   # serialize: later prompts
    for r in reqs():                            # see earlier KV
        sess.submit(r)
    outs = sess.run().results()
    assert [o.tokens for o in outs] == ref
    m = sess.metrics()
    assert m.reused_tokens > 0 and m.cache_hit_rate > 0.0
    reused = [o.lifecycle.cached_len for o in outs]
    assert max(reused) >= len(sysp)             # the shared system prompt


def test_prefix_cache_disabled_is_default(small_model):
    cfg, params = small_model
    coord = Coordinator(cfg, params)
    assert coord.prefix_caches is None
    idx, m = coord.route_prefill(np.array([1, 2, 3], np.int32))
    assert idx == 0 and m is None
