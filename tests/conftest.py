# Tests run on the single real CPU device. Do NOT set
# xla_force_host_platform_device_count here — only the dry-run process
# uses 512 placeholder devices.
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
