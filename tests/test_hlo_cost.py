"""HLO-text cost analyzer: while-trip expansion, dot FLOPs, collectives,
traffic special cases — validated against freshly compiled modules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import analyze_text
from repro.roofline.analysis import collective_bytes


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_trip_expansion_exact():
    X = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    W = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    cost = analyze_text(_compile_text(f, X, W))
    assert cost.flops == pytest.approx(8 * 2 * 64**3, rel=1e-6)


def test_unrolled_matches_scan_flops():
    X = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    W = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)

    def f_scan(x, w):
        out, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return out

    def f_unroll(x, w):
        for i in range(4):
            x = x @ w[i]
        return x

    c1 = analyze_text(_compile_text(f_scan, X, W))
    c2 = analyze_text(_compile_text(f_unroll, X, W))
    assert c1.flops == pytest.approx(c2.flops, rel=1e-6)


def test_nested_scan_multiplies():
    X = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    W = jax.ShapeDtypeStruct((3, 16, 16), jnp.float32)

    def f(x, w):
        def outer(c, _):
            def inner(ci, wi):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, w)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    cost = analyze_text(_compile_text(f, X, W))
    assert cost.flops == pytest.approx(5 * 3 * 2 * 16**3, rel=1e-6)


def test_dus_traffic_counts_slice_not_buffer():
    BIG = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
    SMALL = jax.ShapeDtypeStruct((1, 256), jnp.float32)

    def f(big, small):
        return jax.lax.dynamic_update_slice(big, small, (17, 0))

    cost = analyze_text(_compile_text(f, BIG, SMALL))
    # Without donation XLA inserts one defensive full-buffer copy
    # (read+write = 2×buffer); the DUS itself must contribute only the
    # slice — so total stays under 2.5×buffer instead of 4×+.
    buffer = 4096 * 256 * 4
    assert cost.bytes < 2.5 * buffer


def test_collective_parse_from_sharded_module():
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run under dryrun env)")


def test_legacy_collective_regex():
    text = ("%all-gather.3 = f32[4,256]{0,1} all-gather(%x), dimensions={1}\n"
            "%ar = bf16[8,16]{1,0} all-reduce(%y), to_apply=%sum\n")
    out = collective_bytes(text)
    assert out["all-gather"] == 4 * 256 * 4
    assert out["all-reduce"] == 8 * 16 * 2
