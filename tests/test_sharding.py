"""Sharding rules: every spec is divisibility-safe on the production mesh
shapes for every assigned arch (validated without touching jax devices —
specs are computed from eval_shape + a fake mesh description)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, INPUT_SHAPES, input_specs
from repro.launch import sharding as sr
from repro.models import transformer


class FakeMesh:
    """Duck-typed mesh: shape dict + axis_names (sharding rules only read
    these; NamedSharding construction is monkeypatched out)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


@pytest.fixture(autouse=True)
def patch_named_sharding(monkeypatch):
    import repro.launch.sharding as mod

    def fake(mesh, spec):
        return ("sharding", tuple(spec))

    monkeypatch.setattr(mod, "NamedSharding", fake)
    yield


MESHES = [FakeMesh({"data": 16, "model": 16}),
          FakeMesh({"pod": 2, "data": 16, "model": 16})]


def _check_spec_divisible(shape, spec_tuple, mesh):
    spec = spec_tuple[1]
    for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        assert dim % size == 0, (shape, spec, ax)


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh", MESHES, ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("profile", ["tp", "fsdp_tp"])
def test_param_shardings_divisible(arch, mesh, profile):
    cfg = ARCHS[arch]
    pshape = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    sh = sr.param_shardings(cfg, pshape, mesh, profile)
    flat_s = jax.tree.leaves(sh, is_leaf=lambda x: isinstance(x, tuple)
                             and x and x[0] == "sharding")
    flat_p = jax.tree.leaves(pshape)
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_p, flat_s):
        _check_spec_divisible(leaf.shape, spec, mesh)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "jamba-v0.1-52b",
                                  "xlstm-125m", "whisper-large-v3"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_shardings_divisible(arch, shape_name):
    from repro.launch.steps import pick_config
    mesh = MESHES[0]
    shape = INPUT_SHAPES[shape_name]
    cfg, _ = pick_config(arch, shape)
    cshape = transformer.cache_specs(cfg, shape.global_batch, shape.seq_len)
    sh = sr.cache_shardings(cfg, cshape, mesh, shape.global_batch)
    flat_s = jax.tree.leaves(sh, is_leaf=lambda x: isinstance(x, tuple)
                             and x and x[0] == "sharding")
    flat_c = jax.tree.leaves(cshape)
    for leaf, spec in zip(flat_c, flat_s):
        _check_spec_divisible(leaf.shape, spec, mesh)


def test_long_500k_uses_context_parallel_cache():
    """batch=1 decode shards the KV sequence dim instead of batch."""
    from repro.launch.steps import pick_config
    mesh = MESHES[0]
    shape = INPUT_SHAPES["long_500k"]
    cfg, note = pick_config("yi-34b", shape)
    assert "sliding-window" in note
    cshape = transformer.cache_specs(cfg, 1, shape.seq_len)
    sh = sr.cache_shardings(cfg, cshape, mesh, 1)
    k_spec = sh[0]["k"][1]
    assert k_spec[1] is None            # batch unsharded
    assert k_spec[2] is not None        # seq sharded


def test_fsdp_profile_for_train_and_huge_models():
    from repro.launch.steps import pick_profile
    mesh = MESHES[0]
    assert pick_profile(ARCHS["yi-34b"], "train", mesh) == "fsdp_tp"
    assert pick_profile(ARCHS["llama4-maverick-400b-a17b"], "decode",
                        mesh) == "fsdp_tp"
    assert pick_profile(ARCHS["qwen3-1.7b"], "decode", mesh) == "tp"
