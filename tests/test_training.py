"""Training substrate: optimizer math, data pipeline, checkpointing,
loss decrease."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import init_params
from repro.training import checkpoint as ck
from repro.training import optimizer as opt
from repro.training import train
from repro.training.data import DataConfig, SyntheticTokenStream, host_shard


def test_schedule_warmup_then_decay():
    cfg = opt.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(opt.schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[5] < lrs[10]          # warmup ascending
    assert lrs[99] < lrs[20]                  # decayed
    assert max(lrs) <= cfg.lr + 1e-9


def test_adamw_moves_params_against_gradient():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = opt.init(params)
    cfg = opt.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10)
    new, state2 = opt.apply(cfg, params, grads, state)
    assert float(new["w"].mean()) < 1.0       # moved against +grad
    assert int(state2.step) == 1


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((4,))}
    huge = {"w": jnp.full((4,), 1e9)}
    state = opt.init(params)
    cfg = opt.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                          grad_clip=1.0)
    new, _ = opt.apply(cfg, params, huge, state)
    assert np.isfinite(np.asarray(new["w"])).all()


def test_data_stream_deterministic_and_learnable():
    cfg = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=1)
    s1 = SyntheticTokenStream(cfg).batch(3)
    s2 = SyntheticTokenStream(cfg).batch(3)
    np.testing.assert_array_equal(s1["tokens"], s2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(s1["tokens"][:, 1:], s1["labels"][:, :-1])
    # injected structure: successor repeats more often than chance
    toks, labs = s1["tokens"].ravel(), s1["labels"].ravel()
    stream = SyntheticTokenStream(cfg)
    follows = (stream._succ[toks] == labs).mean()
    assert follows > 0.4


def test_host_shard_slices_batch():
    batch = {"tokens": np.arange(32).reshape(8, 4)}
    sh = host_shard(batch, host_index=1, host_count=2)
    np.testing.assert_array_equal(sh["tokens"], batch["tokens"][4:8])


def test_train_loss_decreases():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    res = train(cfg, steps=25, batch=8, seq=32)
    first = np.mean(res.losses[:3])
    last = np.mean(res.losses[-3:])
    assert last < first * 0.95, (first, last)


def test_checkpoint_roundtrip_with_opt_state():
    cfg = ARCHS["xlstm-125m"].reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    d = tempfile.mkdtemp()
    ck.save(d, 7, params, state)
    assert ck.latest_step(d) == 7
    p2, s2 = ck.restore(d, 7, params, state)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))
    assert int(s2.step) == int(state.step)
