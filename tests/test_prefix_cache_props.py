"""Property-based radix-tree invariants (DESIGN.md §9): insert/match
agrees with a reference longest-common-prefix oracle; eviction honors
the byte budget and never drops a pinned node; refcounts balance."""
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.serving import PrefixCache

seqs_st = st.lists(st.lists(st.integers(0, 5), min_size=1, max_size=10),
                   min_size=1, max_size=10)
probe_st = st.lists(st.integers(0, 5), max_size=12)


def _lcp(a, b):
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


@settings(max_examples=200, deadline=None)
@given(seqs=seqs_st, probe=probe_st)
def test_match_is_longest_common_prefix(seqs, probe):
    pc = PrefixCache()
    for s in seqs:
        assert pc.insert(s) >= 0
    ref = max((_lcp(s, probe) for s in seqs), default=0)
    assert pc.matched_len(probe) == ref
    # every inserted sequence is fully retained (no budget, no eviction)
    for s in seqs:
        assert pc.matched_len(s) == len(s)


@settings(max_examples=200, deadline=None)
@given(seqs=seqs_st)
def test_radix_stores_each_distinct_token_once(seqs):
    """num_tokens equals the trie size of the inserted set — shared
    prefixes are stored exactly once."""
    pc = PrefixCache()
    for s in seqs:
        pc.insert(s)
    trie = {tuple(s[:i + 1]) for s in seqs for i in range(len(s))}
    assert pc.num_tokens == len(trie)
    assert pc.used_bytes == 0.0          # bytes_per_token defaults to 0


@settings(max_examples=150, deadline=None)
@given(seqs=seqs_st, budget=st.integers(1, 24))
def test_eviction_honors_budget_and_pins(seqs, budget):
    pc = PrefixCache(capacity_bytes=budget, bytes_per_token=1.0)
    pinned = seqs[0]
    handle = None
    if pc.insert(pinned) == len(pinned):
        handle = pc.match(pinned, lock=True).node
    for s in seqs[1:]:
        pc.insert(s)
        assert pc.used_bytes <= budget
        # byte accounting always equals the reachable tree (an insert
        # must never orphan nodes or leak their charge)
        assert pc.used_bytes == pc.num_tokens * 1.0
        if handle is not None:
            # eviction never drops a pinned node (nor its ancestors)
            assert pc.matched_len(pinned) == len(pinned)
    if handle is not None:
        pc.unlock(handle)

    def refs(node):
        yield node.refs
        for c in node.children.values():
            yield from refs(c)

    assert all(r == 0 for r in refs(pc.root))


@settings(max_examples=150, deadline=None)
@given(seqs=seqs_st, n_locks=st.integers(0, 4))
def test_refcount_lock_unlock_balance(seqs, n_locks):
    pc = PrefixCache()
    for s in seqs:
        pc.insert(s)
    handles = [pc.match(seqs[i % len(seqs)], lock=True).node
               for i in range(n_locks)]
    # interleave more inserts (splits must preserve pin counts)
    for s in seqs:
        pc.insert(list(s) + [9])
    for h in handles:
        pc.unlock(h)

    def refs(node):
        yield node.refs
        for c in node.children.values():
            yield from refs(c)

    assert all(r == 0 for r in refs(pc.root))
    for i in range(n_locks):
        assert pc.matched_len(seqs[i % len(seqs)]) == len(seqs[i % len(seqs)])
