"""KV handoff helpers: slice_request / pad_capacity / transfer on
attention caches and on SSM/xLSTM (constant-size state) caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import init_params, prefill
from repro.serving import kv_transfer

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def attn_cache():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    params = init_params(KEY, cfg)
    toks = jnp.zeros((3, 6), jnp.int32)
    _, cache = prefill(params, cfg, toks, cache_capacity=8)
    return cfg, cache


@pytest.fixture(scope="module")
def ssm_cache():
    cfg = ARCHS["xlstm-125m"].reduced()
    params = init_params(KEY, cfg)
    toks = jnp.zeros((3, 6), jnp.int32)
    _, cache = prefill(params, cfg, toks, cache_capacity=8)
    return cfg, cache


def test_slice_request_attention(attn_cache):
    _, cache = attn_cache
    for i in range(3):
        one = kv_transfer.slice_request(cache, i)
        for leaf in jax.tree.leaves(one):
            if hasattr(leaf, "ndim") and leaf.ndim >= 2:
                assert leaf.shape[1] == 1  # batch axis collapsed to 1


def test_slice_request_values_match(attn_cache):
    _, cache = attn_cache
    one = kv_transfer.slice_request(cache, 2)
    full = jax.tree.leaves(cache)
    sliced = jax.tree.leaves(one)
    for f, s in zip(full, sliced):
        if hasattr(f, "ndim") and f.ndim >= 2:
            np.testing.assert_array_equal(np.asarray(f[:, 2:3]),
                                          np.asarray(s))


def test_pad_capacity_attention(attn_cache):
    _, cache = attn_cache
    one = kv_transfer.slice_request(cache, 0)
    grown = kv_transfer.pad_capacity(one, 16)
    k, v = grown[0]["k"], grown[0]["v"]
    assert k.shape[2] == 16 and v.shape[2] == 16
    # original prefix preserved, padding zero
    orig_k = one[0]["k"]
    np.testing.assert_array_equal(np.asarray(k[:, :, :orig_k.shape[2]]),
                                  np.asarray(orig_k))
    assert not np.any(np.asarray(k[:, :, orig_k.shape[2]:]))
    assert kv_transfer.transfer_bytes(grown) > kv_transfer.transfer_bytes(one)


def test_pad_capacity_noop_when_large_enough(attn_cache):
    _, cache = attn_cache
    same = kv_transfer.pad_capacity(cache, 8)   # already at capacity 8
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(same)):
        assert a.shape == b.shape


def test_pad_capacity_passes_ssm_state_through(ssm_cache):
    _, cache = ssm_cache
    grown = kv_transfer.pad_capacity(cache, 64)
    # constant-size recurrent state (DESIGN.md §5): no leaf grows
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(grown)):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_slice_request_ssm(ssm_cache):
    _, cache = ssm_cache
    one = kv_transfer.slice_request(cache, 1)
    for full, sl in zip(jax.tree.leaves(cache), jax.tree.leaves(one)):
        if hasattr(full, "ndim") and full.ndim >= 2:
            assert sl.shape[1] == 1
            np.testing.assert_array_equal(np.asarray(full[:, 1:2]),
                                          np.asarray(sl))


@pytest.fixture(scope="module")
def cross_cache():
    cfg = ARCHS["llama-3.2-vision-90b"].reduced()
    params = init_params(KEY, cfg)
    toks = jnp.zeros((2, 6), jnp.int32)
    img = jnp.zeros((2, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    _, cache = prefill(params, cfg, toks, cache_capacity=8,
                       image_embeds=img)
    return cfg, cache


def test_pad_capacity_cross_attention_fixed(cross_cache):
    """Regression (§9 leaf-role hardening): cross-attention K/V share
    the literal k/v names and ndim with self-attention slabs, but their
    'sequence' axis is the image-token count — growing it would feed
    decode's unmasked cross-attention zero-valued memory. With the
    declared roles (cfg passed) only self-attn leaves grow."""
    cfg, cache = cross_cache
    target = 64
    grown = kv_transfer.pad_capacity(cache, target, cfg=cfg)

    def by_role(tree, role):
        out = []

        def visit(path, leaf):
            if kv_transfer.leaf_role(path, leaf, cfg) == role:
                out.append((path, leaf))

        jax.tree_util.tree_map_with_path(visit, tree)
        return out

    cross = by_role(grown, "cross_kv")
    assert cross, "vision cache must contain cross-attention leaves"
    for (path, leaf), (_, orig) in zip(cross, by_role(cache, "cross_kv")):
        assert leaf.shape == orig.shape          # image memory untouched
        np.testing.assert_array_equal(np.asarray(leaf), np.asarray(orig))
    kv = by_role(grown, "kv")
    assert kv, "vision cache must contain self-attention leaves"
    for (path, leaf) in kv:
        assert leaf.shape[kv_transfer.kv_seq_axis(cfg)] == target


def test_leaf_role_heuristic_matches_declared_for_dense(attn_cache):
    """Without cfg the legacy name+ndim heuristic must agree with the
    declared classification on plain dense-attention caches."""
    cfg, cache = attn_cache

    def roles(with_cfg):
        out = []

        def visit(path, leaf):
            out.append(kv_transfer.leaf_role(path, leaf,
                                             cfg if with_cfg else None))

        jax.tree_util.tree_map_with_path(visit, cache)
        return out

    assert roles(True) == roles(False)
    assert set(roles(True)) == {"kv"}


def test_slab_capacity(attn_cache):
    cfg, cache = attn_cache
    assert kv_transfer.slab_capacity(cache, cfg) == 8
    grown = kv_transfer.pad_capacity(cache, 16, cfg=cfg)
    assert kv_transfer.slab_capacity(grown, cfg) == 16


def test_transfer_identity_without_shardings(attn_cache):
    _, cache = attn_cache
    out = kv_transfer.transfer(cache)   # no dst shardings: placement kept
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_transfer_bytes_counts_all_leaves(attn_cache):
    _, cache = attn_cache
    total = sum(leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree.leaves(cache))
    assert kv_transfer.transfer_bytes(cache) == total


# ---------------------------------------------------------------------------
# Full arch-pool coverage: pad_capacity / slice_request / transfer_bytes
# across GQA, MoE, SWA, mamba-hybrid, and vision cross-attention caches
# (pre-§10, only the cross-attn regression covered non-vanilla caches).
# ---------------------------------------------------------------------------

#: (arch id, swa variant?, roles its cache must contain)
POOL = [
    ("qwen2.5-32b", False, {"kv"}),                    # GQA dense
    ("qwen3-moe-30b-a3b", False, {"kv"}),              # MoE
    ("qwen3-1.7b", True, {"window_kv", "window_pos"}),  # sliding window
    ("jamba-v0.1-52b", False, {"kv", "state"}),        # mamba hybrid
    ("llama-3.2-vision-90b", False, {"kv", "cross_kv"}),  # vision x-attn
]


@pytest.fixture(scope="module", params=POOL,
                ids=[f"{a}{'-swa' if s else ''}" for a, s, _ in POOL])
def pool_cache(request):
    arch, swa, roles = request.param
    cfg = ARCHS[arch]
    if swa:
        cfg = cfg.with_sliding_window(64)
    cfg = cfg.reduced()
    params = init_params(KEY, cfg)
    toks = jnp.zeros((2, 6), jnp.int32)
    extra = {}
    if cfg.num_image_tokens:
        extra["image_embeds"] = jnp.zeros(
            (2, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    _, cache = prefill(params, cfg, toks, cache_capacity=8, **extra)
    return cfg, cache, roles


def _roles(cfg, cache):
    found = {}

    def visit(path, leaf):
        found.setdefault(kv_transfer.leaf_role(path, leaf, cfg),
                         []).append((path, leaf))

    jax.tree_util.tree_map_with_path(visit, cache)
    return found


def test_pool_declared_roles_present(pool_cache):
    cfg, cache, expected = pool_cache
    assert expected <= set(_roles(cfg, cache))


def test_pool_slice_request(pool_cache):
    cfg, cache, _ = pool_cache
    one = kv_transfer.slice_request(cache, 1)
    for full, sl in zip(jax.tree.leaves(cache), jax.tree.leaves(one)):
        if hasattr(full, "ndim") and full.ndim >= 2:
            assert sl.shape[1] == 1
            np.testing.assert_array_equal(np.asarray(full[:, 1:2]),
                                          np.asarray(sl))


def test_pool_pad_capacity_grows_only_kv(pool_cache):
    cfg, cache, _ = pool_cache
    target = 32
    grown = kv_transfer.pad_capacity(cache, target, cfg=cfg)
    axis = kv_transfer.kv_seq_axis(cfg)
    saw_kv = False
    for (path, leaf), (_, orig) in zip(
            jax.tree_util.tree_flatten_with_path(grown)[0],
            jax.tree_util.tree_flatten_with_path(cache)[0]):
        role = kv_transfer.leaf_role(path, leaf, cfg)
        if role == "kv":
            saw_kv = True
            assert leaf.shape[axis] == target
            # original prefix preserved, padding zero
            sl = [slice(None)] * leaf.ndim
            sl[axis] = slice(0, orig.shape[axis])
            np.testing.assert_array_equal(np.asarray(leaf[tuple(sl)]),
                                          np.asarray(orig))
            sl[axis] = slice(orig.shape[axis], None)
            assert not np.any(np.asarray(leaf[tuple(sl)],
                                         np.float32))
        else:
            # window rings, cross memory, recurrent state: untouched
            assert leaf.shape == orig.shape
            np.testing.assert_array_equal(np.asarray(leaf),
                                          np.asarray(orig))
    assert saw_kv == ("kv" in _roles(cfg, cache))


def test_pool_transfer_bytes_and_codec(pool_cache):
    cfg, cache, roles = pool_cache
    raw = kv_transfer.transfer_bytes(cache)
    assert raw == sum(leaf.size * leaf.dtype.itemsize
                      for leaf in jax.tree.leaves(cache))
    wire = kv_transfer.transfer_bytes(cache, codec="int8", cfg=cfg)
    # every pool arch carries quantizable float KV (full or windowed)
    assert wire < raw


def test_pool_slab_capacity(pool_cache):
    cfg, cache, roles = pool_cache
    cap = kv_transfer.slab_capacity(cache, cfg)
    assert cap == (8 if "kv" in roles else 0)
