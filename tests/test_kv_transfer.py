"""KV handoff helpers: slice_request / pad_capacity / transfer on
attention caches and on SSM/xLSTM (constant-size state) caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import init_params, prefill
from repro.serving import kv_transfer

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def attn_cache():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    params = init_params(KEY, cfg)
    toks = jnp.zeros((3, 6), jnp.int32)
    _, cache = prefill(params, cfg, toks, cache_capacity=8)
    return cfg, cache


@pytest.fixture(scope="module")
def ssm_cache():
    cfg = ARCHS["xlstm-125m"].reduced()
    params = init_params(KEY, cfg)
    toks = jnp.zeros((3, 6), jnp.int32)
    _, cache = prefill(params, cfg, toks, cache_capacity=8)
    return cfg, cache


def test_slice_request_attention(attn_cache):
    _, cache = attn_cache
    for i in range(3):
        one = kv_transfer.slice_request(cache, i)
        for leaf in jax.tree.leaves(one):
            if hasattr(leaf, "ndim") and leaf.ndim >= 2:
                assert leaf.shape[1] == 1  # batch axis collapsed to 1


def test_slice_request_values_match(attn_cache):
    _, cache = attn_cache
    one = kv_transfer.slice_request(cache, 2)
    full = jax.tree.leaves(cache)
    sliced = jax.tree.leaves(one)
    for f, s in zip(full, sliced):
        if hasattr(f, "ndim") and f.ndim >= 2:
            np.testing.assert_array_equal(np.asarray(f[:, 2:3]),
                                          np.asarray(s))


def test_pad_capacity_attention(attn_cache):
    _, cache = attn_cache
    one = kv_transfer.slice_request(cache, 0)
    grown = kv_transfer.pad_capacity(one, 16)
    k, v = grown[0]["k"], grown[0]["v"]
    assert k.shape[2] == 16 and v.shape[2] == 16
    # original prefix preserved, padding zero
    orig_k = one[0]["k"]
    np.testing.assert_array_equal(np.asarray(k[:, :, :orig_k.shape[2]]),
                                  np.asarray(orig_k))
    assert not np.any(np.asarray(k[:, :, orig_k.shape[2]:]))
    assert kv_transfer.transfer_bytes(grown) > kv_transfer.transfer_bytes(one)


def test_pad_capacity_noop_when_large_enough(attn_cache):
    _, cache = attn_cache
    same = kv_transfer.pad_capacity(cache, 8)   # already at capacity 8
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(same)):
        assert a.shape == b.shape


def test_pad_capacity_passes_ssm_state_through(ssm_cache):
    _, cache = ssm_cache
    grown = kv_transfer.pad_capacity(cache, 64)
    # constant-size recurrent state (DESIGN.md §5): no leaf grows
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(grown)):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_slice_request_ssm(ssm_cache):
    _, cache = ssm_cache
    one = kv_transfer.slice_request(cache, 1)
    for full, sl in zip(jax.tree.leaves(cache), jax.tree.leaves(one)):
        if hasattr(full, "ndim") and full.ndim >= 2:
            assert sl.shape[1] == 1
            np.testing.assert_array_equal(np.asarray(full[:, 1:2]),
                                          np.asarray(sl))


def test_transfer_identity_without_shardings(attn_cache):
    _, cache = attn_cache
    out = kv_transfer.transfer(cache)   # no dst shardings: placement kept
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_transfer_bytes_counts_all_leaves(attn_cache):
    _, cache = attn_cache
    total = sum(leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree.leaves(cache))
    assert kv_transfer.transfer_bytes(cache) == total
