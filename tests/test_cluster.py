"""Cluster specs: construction invariants, paper settings, link tiers."""
import numpy as np
import pytest

from repro.core.cluster import (GPU_TYPES, LINK_ETH_SLOW, PAPER_SETTINGS,
                                build_cluster)


def test_build_cluster_shapes_and_symmetry():
    cl = build_cluster([("H100", 2), ("A6000", 3)])
    assert cl.num_devices == 5
    assert cl.bandwidth.shape == (5, 5)
    assert np.allclose(cl.bandwidth, cl.bandwidth.T)
    assert np.all(np.diag(cl.bandwidth) == 0)
    assert np.all(cl.bandwidth[~np.eye(5, dtype=bool)] > 0)


def test_intra_node_faster_than_inter_node():
    cl = build_cluster([("A100", 2), ("A100", 2)])
    intra = cl.bandwidth[0, 1]   # same node (NVLink)
    inter = cl.bandwidth[0, 2]   # across nodes
    assert intra > inter


def test_slow_pairs_apply_cross_dc_tier():
    cl = build_cluster([("L40", 2), ("L40", 2)], slow_pairs=[(0, 1)])
    assert cl.bandwidth[0, 2] == pytest.approx(LINK_ETH_SLOW[0])


@pytest.mark.parametrize("name", list(PAPER_SETTINGS))
def test_paper_settings_construct(name):
    cl = PAPER_SETTINGS[name]()
    assert cl.num_devices >= 4
    assert cl.price_per_hour > 0
    # budgets in the rough neighbourhood of Figure 4's captions
    if name == "homogeneous":
        assert 25 < cl.price_per_hour < 32
    if name == "hetero5":
        assert cl.price_per_hour < 25  # the 70%-budget setting


def test_gpu_type_ordering():
    assert GPU_TYPES["H100"].flops > GPU_TYPES["A100"].flops > \
        GPU_TYPES["L40"].flops > GPU_TYPES["A6000"].flops
    assert GPU_TYPES["H100"].hbm_bandwidth > GPU_TYPES["A6000"].hbm_bandwidth
