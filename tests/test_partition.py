"""Graph-partition phase: spectral + KL invariants (property tests)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import LLAMA2_70B, OPT_30B
from repro.core.cluster import (PAPER_SETTINGS, heterogeneous_setting_1,
                                homogeneous_setting)
from repro.core.partition import (GroupPartition, coarsen, initial_partition,
                                  kernighan_lin, num_groups,
                                  secondary_partition, spectral_partition)


def _cut(weights, labels):
    n = weights.shape[0]
    return sum(weights[i, j] for i in range(n) for j in range(i + 1, n)
               if labels[i] != labels[j])


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 12), st.integers(2, 4), st.integers(0, 10_000))
def test_spectral_partition_covers_all(n, k, seed):
    rng = np.random.default_rng(seed)
    w = rng.random((n, n))
    w = (w + w.T) / 2
    np.fill_diagonal(w, 0)
    labels = spectral_partition(w, k)
    assert len(labels) == n
    assert set(labels) <= set(range(k))


def test_spectral_finds_obvious_clusters():
    # two cliques connected by a weak bridge
    w = np.zeros((8, 8))
    for grp in ([0, 1, 2, 3], [4, 5, 6, 7]):
        for i in grp:
            for j in grp:
                if i != j:
                    w[i, j] = 10.0
    w[3, 4] = w[4, 3] = 0.1
    labels = spectral_partition(w, 2, np.ones(8))
    assert len({labels[i] for i in [0, 1, 2, 3]}) == 1
    assert len({labels[i] for i in [4, 5, 6, 7]}) == 1
    assert labels[0] != labels[7]


@settings(max_examples=20, deadline=None)
@given(st.integers(6, 10), st.integers(0, 10_000))
def test_kl_never_worsens_cut(n, seed):
    rng = np.random.default_rng(seed)
    w = rng.random((n, n))
    w = (w + w.T) / 2
    np.fill_diagonal(w, 0)
    labels = [i % 2 for i in range(n)]
    nw = np.ones(n)
    refined = kernighan_lin(w, labels, nw)
    assert _cut(w, refined) <= _cut(w, labels) + 1e-9


def test_kl_maximize_raises_cut():
    rng = np.random.default_rng(1)
    w = rng.random((8, 8))
    w = (w + w.T) / 2
    np.fill_diagonal(w, 0)
    labels = [i % 2 for i in range(8)]
    refined = kernighan_lin(w, labels, np.ones(8), maximize=True)
    assert _cut(w, refined) >= _cut(w, labels) - 1e-9


def test_coarsen_sums_cross_weights():
    w = np.arange(16, dtype=float).reshape(4, 4)
    w = (w + w.T) / 2
    np.fill_diagonal(w, 0)
    groups = [[0, 1], [2, 3]]
    c = coarsen(w, groups)
    assert c[0, 1] == pytest.approx(w[0, 2] + w[0, 3] + w[1, 2] + w[1, 3])


def test_secondary_partition_has_both_types():
    cw = np.ones((4, 4)) - np.eye(4)
    cap = np.array([4.0, 3.0, 2.0, 1.0])
    is_prefill = secondary_partition(cw, cap)
    assert any(is_prefill) and not all(is_prefill)


@pytest.mark.parametrize("setting", list(PAPER_SETTINGS))
@pytest.mark.parametrize("profile", [OPT_30B, LLAMA2_70B])
def test_initial_partition_valid_on_paper_settings(setting, profile):
    cluster = PAPER_SETTINGS[setting]()
    if profile is LLAMA2_70B and cluster.total_memory < 300e9:
        pytest.skip("cluster too small for 70B")
    part = initial_partition(cluster, profile)
    part.validate(cluster.num_devices)  # covers all devices, both types
    # groups respect the memory-based count heuristic loosely
    assert 2 <= part.num_groups <= cluster.num_devices


def test_num_groups_shrinks_with_model_size():
    cl = heterogeneous_setting_1()
    assert num_groups(cl, LLAMA2_70B) <= num_groups(cl, OPT_30B)
