"""§10 KV-handoff pipeline, scheduling-domain side: the staged/blocking
simulator model, chunked-overlap TTFT wins on a bandwidth-skewed
cluster, the codec ratio changing max-flow decisions, the cost-model
transfer terms, and the sim-vs-runtime byte-accounting parity."""
import numpy as np
import pytest

from repro.core import LLAMA2_70B, WORKLOADS, make_plan
from repro.core.cluster import homogeneous_setting, kv_skewed_setting
from repro.core.cost_model import (ModelProfile, dtype_bytes,
                                   kv_transfer_time)
from repro.core.flowgraph import solve_flow
from repro.core.partition import GroupPartition
from repro.core.placement import Placement, ReplicaPlacement
from repro.serving import METRIC_FIELDS, offline_workload, simulate
from repro.serving.kv_compression import profile_kv_ratio

WL = WORKLOADS["HPLD"]


def _skewed_placement(cl, profile):
    """2 prefill + 2 decode replicas; every KV edge crosses the starved
    inter-node fabric (kv_skewed_setting nodes: H100 pair, A100 pair,
    two A6000 pairs)."""
    reps, routes = [], {}
    for g, devs in enumerate(([0, 1], [2, 3], [4, 5], [6, 7])):
        plan = make_plan([devs], profile.num_layers, cl)
        reps.append(ReplicaPlacement(g, devs, g < 2, plan, 1.0))
    for p in range(2):
        for d in (2, 3):
            routes[(p, d)] = 1.0
    return Placement(reps, routes, max_flow=4.0, period=600.0)


# -- cost-model transfer terms ----------------------------------------------


def test_kv_transfer_time_compression_and_chunking():
    cl = kv_skewed_setting()
    src = make_plan([[0, 1]], LLAMA2_70B.num_layers, cl)
    dst = make_plan([[4, 5]], LLAMA2_70B.num_layers, cl)
    base = kv_transfer_time(cl, LLAMA2_70B, src, dst, 1, 1024)
    # defaults reproduce the pre-§10 formula
    assert kv_transfer_time(cl, LLAMA2_70B, src, dst, 1, 1024,
                            compression_ratio=1.0, chunks=1) == base
    half = kv_transfer_time(cl, LLAMA2_70B, src, dst, 1, 1024,
                            compression_ratio=2.0)
    assert half < base and half == pytest.approx(base / 2, rel=1e-3)
    chunked = kv_transfer_time(cl, LLAMA2_70B, src, dst, 1, 1024, chunks=8)
    assert chunked < base and chunked >= base / 8
    both = kv_transfer_time(cl, LLAMA2_70B, src, dst, 1, 1024,
                            compression_ratio=2.0, chunks=8)
    assert both < half and both < chunked


def test_dtype_bytes_and_kv_dtype_profiles():
    assert dtype_bytes("fp16") == dtype_bytes(np.float16) == 2.0
    assert dtype_bytes("bf16") == 2.0 and dtype_bytes("int8") == 1.0
    with pytest.raises(KeyError):
        dtype_bytes("fp4")
    args = dict(num_layers=4, hidden=64, ffn=128, num_heads=4, kv_heads=2,
                vocab=100, head_dim=16)
    fp16 = ModelProfile.dense("p16", **args)
    int8 = ModelProfile.dense("p8", kv_dtype="int8", **args)
    fp32 = ModelProfile.dense("p32", kv_dtype="fp32", **args)
    # KV bytes derive from the declared dtype, not the fp16 constant
    assert int8.kv_bytes_token_layer == fp16.kv_bytes_token_layer / 2
    assert fp32.kv_bytes_token_layer == fp16.kv_bytes_token_layer * 2
    assert int8.kv_elem_bytes == 1.0 and int8.kv_quant_group == 16
    # params are unaffected (the satellite fix targets KV pricing only)
    assert int8.param_bytes_layer == fp16.param_bytes_layer
    # an int8-KV profile gains nothing from the int8 codec
    assert profile_kv_ratio(int8, "int8") == 1.0
    assert profile_kv_ratio(fp32, "int8") > profile_kv_ratio(fp16, "int8") > 1


def test_from_arch_matches_arch_shapes():
    from repro.configs import ARCHS
    cfg = ARCHS["qwen3-1.7b"].reduced()
    prof = ModelProfile.from_arch(cfg, kv_dtype="bf16")
    assert prof.num_layers == cfg.num_layers
    assert prof.kv_bytes_token_layer == 2.0 * cfg.kv_dim * 2.0
    assert prof.kv_quant_group == cfg.head_dim
    hybrid = ModelProfile.from_arch(ARCHS["jamba-v0.1-52b"].reduced())
    assert 0.0 < hybrid.attn_layer_fraction < 1.0
    assert hybrid.state_bytes_layer > 0


# -- simulator pipeline model -----------------------------------------------


def _sim(codec, n=24):
    cl = kv_skewed_setting()
    placement = _skewed_placement(cl, LLAMA2_70B)
    reqs = offline_workload("HPLD", n, seed=5)
    return simulate(cl, LLAMA2_70B, placement, reqs, kv_codec=codec)


def test_chunked_compressed_beats_blocking_ttft():
    """The §10 acceptance check, deterministic at toy size: on a
    bandwidth-skewed cluster, int8+chunked streaming must beat the
    blocking uncompressed handoff on mean TTFT (and int8 alone must
    already help)."""
    none, int8, chunked = (_sim(c) for c in ("none", "int8",
                                             "int8-chunked"))
    assert chunked.avg_ttft < int8.avg_ttft < none.avg_ttft
    assert chunked.avg_latency < none.avg_latency
    # compression accounting
    assert none.kv_compression_ratio == 1.0
    assert int8.kv_compression_ratio == pytest.approx(
        chunked.kv_compression_ratio)
    assert int8.kv_compression_ratio > 1.5
    assert chunked.kv_bytes_shipped < none.kv_bytes_shipped
    # only the chunked codec hides transfer behind prefill compute
    assert none.transfer_overlap_frac == 0.0
    assert int8.transfer_overlap_frac == 0.0
    assert 0.0 < chunked.transfer_overlap_frac <= 1.0


def test_legacy_none_keeps_detached_handoff():
    """kv_codec=None (legacy abstraction) must not pay the staged
    blocking handoff the explicit "none" codec models."""
    legacy = _sim(None)
    blocking = _sim("none")
    assert legacy.avg_ttft < blocking.avg_ttft
    # legacy still stamps exact-codec accounting
    assert legacy.kv_compression_ratio == 1.0
    assert legacy.kv_bytes_shipped == blocking.kv_bytes_shipped


def test_single_token_requests_ship_no_kv():
    from repro.serving import Request
    cl = homogeneous_setting()
    placement = _skewed_placement(cl, LLAMA2_70B)
    reqs = [Request(rid=i, s_in=64, s_out=1, arrival=0.0) for i in range(3)]
    out = simulate(cl, LLAMA2_70B, placement, reqs, kv_codec="int8")
    assert out.kv_bytes_shipped == 0.0
    assert all(r.latency is not None for r in reqs)
    assert out.decode_tokens == 3


def test_metric_fields_cover_kv_handoff():
    for field in ("kv_bytes_shipped", "kv_compression_ratio",
                  "transfer_overlap_frac"):
        assert field in METRIC_FIELDS
    r = _sim("int8-chunked", n=6)
    summary = r.summary()
    for field in ("kv_bytes_shipped", "kv_compression_ratio",
                  "transfer_overlap_frac"):
        assert np.isfinite(summary[field])


# -- scheduler feedback -----------------------------------------------------


def test_codec_ratio_changes_flow_assignment():
    """Feeding the codec ratio into the φ→δ edge capacities must change
    at least one scheduler decision on the bandwidth-skewed cluster —
    here the max-flow KV assignment itself (the §10 acceptance check)."""
    cl = kv_skewed_setting()
    part = GroupPartition([[0, 1], [2, 3], [4, 5], [6, 7]],
                          [True, False, False, False])
    ratio = profile_kv_ratio(LLAMA2_70B, "int8")
    assert ratio > 1.5
    raw = solve_flow(cl, LLAMA2_70B, part, WL)
    comp = solve_flow(cl, LLAMA2_70B, part, WL, kv_compression_ratio=ratio)
    assert comp.placement.max_flow > raw.placement.max_flow * 1.2
    assert {k: round(v, 6) for k, v in raw.placement.kv_routes.items()} \
        != {k: round(v, 6) for k, v in comp.placement.kv_routes.items()}


# -- sim-vs-runtime parity (METRIC_FIELDS contract) -------------------------


def test_sim_runtime_kv_bytes_parity():
    import jax
    from repro.configs import ARCHS
    from repro.models import init_params
    from repro.models.common import DEFAULT_DTYPE
    from repro.serving import Coordinator, ServeRequest, multi_turn_workload

    cfg = ARCHS["qwen3-1.7b"].reduced()
    prof = ModelProfile.from_arch(cfg, kv_dtype=DEFAULT_DTYPE)
    trace = dict(conversations=3, turns=2, rate_rps=4.0, system_len=10,
                 user_len=5, out_len=4)

    cl = homogeneous_setting()
    sim = simulate(cl, prof, _skewed_placement(cl, prof),
                   multi_turn_workload(seed=9, vocab=cfg.vocab, **trace),
                   kv_codec="int8")

    params = init_params(jax.random.PRNGKey(0), cfg)
    coord = Coordinator(cfg, params, num_decode_engines=2,
                        slots_per_engine=6, capacity=128,
                        num_prefill_engines=2, kv_codec="int8")
    sess = coord.session(max_prefill_batch=1)
    for r in sorted(multi_turn_workload(seed=9, vocab=cfg.vocab, **trace),
                    key=lambda r: r.arrival):
        sess.submit(ServeRequest(r.rid, np.asarray(r.tokens, np.int32),
                                 r.s_out), arrival_time=r.arrival)
    m = sess.run().metrics()
    # per-request stamps are identical; the sums are compared at 1e-12
    # relative (the domains iterate requests in different orders, so
    # float non-associativity may break bit equality)
    assert m.kv_bytes_shipped > 0
    assert sim.kv_bytes_shipped == pytest.approx(m.kv_bytes_shipped,
                                                 rel=1e-12)
    assert sim.kv_compression_ratio == pytest.approx(
        m.kv_compression_ratio, abs=1e-9)
