"""Paged KV decode (DESIGN.md §11): kernel vs oracle, paged-vs-dense
bit-identity across the arch pool, page lifecycle in engines/sim, and
the cross-domain page-count parity contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.kernels import ref
from repro.kernels.decode_attention import gqa_paged_decode_bhsd
from repro.models import init_params, transformer
from repro.serving import (Coordinator, ServeRequest, kv_compression,
                           kv_transfer)
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.metrics import METRIC_FIELDS
from repro.serving.paging import (NoFreeSlotError, OutOfPagesError,
                                  PagePool, pages_for, pages_for_request)

KEY = jax.random.PRNGKey(7)
PS = 16


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------


PAGED_CASES = [
    # (b, hq, hkv, hd, page_size, num_blocks, num_pages)
    (1, 4, 4, 64, 16, 4, 8),
    (2, 8, 2, 64, 32, 8, 24),       # GQA group 4
    (3, 4, 1, 128, 16, 8, 32),      # MQA
    (2, 4, 2, 96, 64, 4, 12),       # non-pow2 head dim
]


@pytest.mark.parametrize("b,hq,hkv,hd,ps,nb,npages", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kernel_matches_oracle(b, hq, hkv, hd, ps, nb, npages,
                                     dtype):
    k1, k2, k3, k4, k5 = jax.random.split(KEY, 5)
    q = _rand(k1, (b, hq, hd), dtype)
    kp = _rand(k2, (npages, hkv, ps, hd), dtype)
    vp = _rand(k3, (npages, hkv, ps, hd), dtype)
    bt = jax.random.randint(k4, (b, nb), 0, npages)
    vl = jax.random.randint(k5, (b,), 1, nb * ps + 1)
    out = gqa_paged_decode_bhsd(q, kp, vp, bt, vl, interpret=True)
    expect = ref.gqa_paged_decode_ref(q, kp, vp, bt, vl)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_paged_kernel_ignores_pages_past_valid_len():
    """Rewriting pages past valid_len (scratch / other slots' pages)
    must not change the output."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (2, 4, 64))
    kp = _rand(k2, (16, 2, 16, 64))
    vp = _rand(k3, (16, 2, 16, 64))
    bt = jnp.arange(2 * 6, dtype=jnp.int32).reshape(2, 6) % 16
    vl = jnp.array([20, 50])
    out1 = gqa_paged_decode_bhsd(q, kp, vp, bt, vl, interpret=True)
    # pages backing blocks >= ceil(vl/ps) are dead weight
    kp2 = kp.at[jnp.asarray(bt[0, 2:])].set(99.0)
    kp2 = kp2.at[jnp.asarray(bt[1, 4:])].set(-99.0)
    vp2 = vp.at[jnp.asarray(bt[0, 2:])].set(-7.0)
    out2 = gqa_paged_decode_bhsd(q, kp2, vp2, bt, vl, interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_paged_kernel_aot_lowers_for_tpu():
    qd = jax.ShapeDtypeStruct((4, 16, 128), jnp.bfloat16)
    pool = jax.ShapeDtypeStruct((64, 2, 128, 128), jnp.bfloat16)
    bt = jax.ShapeDtypeStruct((4, 16), jnp.int32)
    vl = jax.ShapeDtypeStruct((4,), jnp.int32)
    tr = jax.jit(gqa_paged_decode_bhsd).trace(qd, pool, pool, bt, vl)
    txt = tr.lower(lowering_platforms=("tpu",)).as_text()
    assert "tpu_custom_call" in txt


# ---------------------------------------------------------------------------
# Paged vs dense bit-identity across the arch pool
# ---------------------------------------------------------------------------


def _mixed_swa(cfg):
    """llama4 variant with one attn block turned sliding-window: paged
    full-attention pools coexist with a dense SWA ring."""
    period = (cfg.period[0],
              dataclasses.replace(cfg.period[1], mixer="swa"))
    return dataclasses.replace(cfg, period=period, sliding_window=32,
                               name=cfg.name + "+swa")


ARCH_POOL = {
    "gqa": lambda: ARCHS["qwen3-1.7b"].reduced(),
    "moe": lambda: ARCHS["qwen3-moe-30b-a3b"].reduced(),
    "swa": lambda: _mixed_swa(ARCHS["llama4-maverick-400b-a17b"].reduced()),
    "jamba": lambda: ARCHS["jamba-v0.1-52b"].reduced(),
    "vision": lambda: ARCHS["llama-3.2-vision-90b"].reduced(),
    "kmajor": lambda: dataclasses.replace(
        ARCHS["qwen2.5-32b"].reduced(), kv_layout="kmajor",
        name="qwen2.5-32b-reduced-kmajor"),
}


@pytest.mark.parametrize("family", sorted(ARCH_POOL))
def test_paged_vs_dense_bit_identity(family):
    """Dense and paged decode must produce bit-identical (at minimum
    argmax-stable) logits: the gathered page view is shape- and
    value-identical to the dense slab, so reductions match."""
    cfg = ARCH_POOL[family]()
    params = init_params(KEY, cfg)
    cap, steps = 64, 4
    extra = {}
    if cfg.num_image_tokens:
        extra["image_embeds"] = np.zeros(
            (1, cfg.num_image_tokens, cfg.d_model), np.float32)
    pe = PrefillEngine(cfg, params, cache_capacity=cap)
    dense = DecodeEngine(cfg, params, slots=2, capacity=cap)
    paged = DecodeEngine(cfg, params, slots=2, capacity=cap, paged=True,
                         page_size=PS)
    rng = np.random.default_rng(4)
    for rid, n in enumerate((13, 26)):
        prompt = rng.integers(0, cfg.vocab, n).astype(np.int32)
        first, slab = pe.prefill_batch([prompt], [extra])[0]
        dense.admit(rid, first, n, steps + 1,
                    kv_transfer.pad_capacity(slab, cap, cfg=cfg))
        paged.admit(rid, first, n, steps + 1,
                    kv_transfer.trim_to_pages(slab, n, PS, cfg=cfg))
    for _ in range(steps):
        out_d = dense.step()
        out_p = paged.step()
        assert out_d == out_p, (cfg.name, out_d, out_p)


def test_decode_step_paged_logits_bit_identical():
    """Model-level check: raw logits (not just argmax) are bitwise
    equal between decode_step and decode_step_paged when the gathered
    view has the dense capacity."""
    cfg = ARCHS["qwen3-1.7b"].reduced()
    params = init_params(KEY, cfg)
    cap, slots = 64, 2
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (10, 23)]
    dense = transformer.init_cache(cfg, slots, cap)
    paged = transformer.init_paged_cache(cfg, slots, cap // PS * slots + 1,
                                         PS)
    bt = np.full((slots, cap // PS), -1, np.int32)
    toks = np.zeros((slots,), np.int32)
    lens = np.zeros((slots,), np.int32)
    nxt = 1
    for i, p in enumerate(prompts):
        logits, cache = transformer.prefill(params, cfg,
                                            jnp.asarray(p)[None],
                                            cache_capacity=cap)
        toks[i] = int(np.argmax(np.asarray(logits)[0]))
        lens[i] = len(p) + 1
        dense = jax.tree.map(
            lambda d, s: jax.lax.dynamic_update_slice_in_dim(
                d, s.astype(d.dtype), i, axis=1)
            if hasattr(d, "ndim") and d.ndim >= 2 else d, dense, cache)
        n_pg = pages_for(len(p), PS)
        pages = list(range(nxt, nxt + n_pg))
        nxt += n_pg
        bt[i, :n_pg] = pages
        new = []
        for spec, pc, src in zip(cfg.period, paged, cache):
            if spec.mixer == "attn":
                d = dict(pc)
                for nm in ("k", "v"):
                    for j, pg in enumerate(pages):
                        chunk = jax.lax.dynamic_slice_in_dim(
                            src[nm][:, 0], j * PS, PS, axis=1)
                        d[nm] = d[nm].at[:, pg].set(
                            chunk.astype(d[nm].dtype))
                new.append(d)
            else:
                new.append(jax.tree.map(
                    lambda d, s: jax.lax.dynamic_update_slice_in_dim(
                        d, s.astype(d.dtype), i, axis=1)
                    if hasattr(d, "ndim") and d.ndim >= 2 else d, pc, src))
        paged = tuple(new)
    for step in range(3):
        pos = np.maximum(lens - 1, 0).astype(np.int32)
        ld, dense = transformer.decode_step(
            params, cfg, dense, jnp.asarray(toks)[:, None],
            jnp.asarray(pos)[:, None])
        lp, paged = transformer.decode_step_paged(
            params, cfg, paged, jnp.asarray(toks)[:, None],
            jnp.asarray(pos)[:, None], jnp.asarray(bt), PS)
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))
        toks = np.asarray(jnp.argmax(ld, axis=-1), np.int32)
        lens += 1
        for i in range(slots):
            need = pages_for(int(lens[i]), PS)
            have = int((bt[i] >= 0).sum())
            if need > have:
                bt[i, have] = nxt
                nxt += 1


# ---------------------------------------------------------------------------
# Engine page lifecycle
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_rt():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    return cfg, init_params(KEY, cfg)


def test_admit_errors_are_explicit(small_rt):
    cfg, params = small_rt
    pe = PrefillEngine(cfg, params, cache_capacity=64)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 20).astype(np.int32)
    first, slab = pe.prefill_batch([prompt])[0]

    dense = DecodeEngine(cfg, params, slots=1, capacity=64)
    dense.admit(0, first, 20, 4, kv_transfer.pad_capacity(slab, 64,
                                                          cfg=cfg))
    with pytest.raises(NoFreeSlotError):
        dense.admit(1, first, 20, 4,
                    kv_transfer.pad_capacity(slab, 64, cfg=cfg))
    with pytest.raises(NoFreeSlotError):
        dense.admit_chunked(2, first, 20, 4, [])

    tiny = DecodeEngine(cfg, params, slots=2, capacity=64, paged=True,
                        page_size=PS, num_pages=3)   # 2 usable pages
    trimmed = kv_transfer.trim_to_pages(slab, 20, PS, cfg=cfg)
    tiny.admit(0, first, 20, 4, trimmed)
    free_before = tiny.pool.free_pages
    with pytest.raises(OutOfPagesError):
        tiny.admit(1, first, 20, 4, trimmed)
    assert tiny.pool.free_pages == free_before   # failure left no debris


def test_page_reclamation_and_stamps(small_rt):
    cfg, params = small_rt
    pe = PrefillEngine(cfg, params, cache_capacity=64)
    eng = DecodeEngine(cfg, params, slots=3, capacity=64, paged=True,
                       page_size=PS)
    rng = np.random.default_rng(2)
    jobs = [(0, 15, 4), (1, 17, 3), (2, 30, 5)]   # (rid, s_in, s_out)
    for rid, s_in, s_out in jobs:
        prompt = rng.integers(0, cfg.vocab, s_in).astype(np.int32)
        first, slab = pe.prefill_batch([prompt])[0]
        eng.admit(rid, first, s_in, s_out,
                  kv_transfer.trim_to_pages(slab, s_in, PS, cfg=cfg))
    assert eng.pool.pages_in_use == sum(pages_for(s, PS)
                                        for _, s, _ in jobs)
    while any(s.active for s in eng.slots):
        eng.step()
    assert eng.pool.pages_in_use == 0             # reclaimed on finish
    for rid, s_in, s_out in jobs:
        assert eng.pop_page_stamp(rid) == pages_for_request(s_in, s_out,
                                                            PS)


def test_cow_prefix_sharing_bit_identical(small_rt):
    """Two engines — one sharing prefix pages CoW, one cold — must
    decode identically; the shared run allocates fewer fresh pages and
    the pinned slab survives decode writes (the boundary page was
    copied, not aliased)."""
    cfg, params = small_rt
    pe = PrefillEngine(cfg, params, cache_capacity=96)
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, cfg.vocab, 37).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(0, cfg.vocab, k)
                               .astype(np.int32)]) for k in (5, 9)]
    outs = {}
    for mode in ("cold", "shared"):
        eng = DecodeEngine(cfg, params, slots=2, capacity=96, paged=True,
                           page_size=PS,
                           share_prefix_pages=(mode == "shared"))
        for rid, p in enumerate(prompts):
            first, slab = pe.prefill_batch([p])[0]
            eng.admit(rid, first, len(p), 5,
                      kv_transfer.trim_to_pages(slab, len(p), PS, cfg=cfg),
                      tokens=p)
        outs[mode] = [eng.step() for _ in range(5)]
        if mode == "shared":
            # 37-token prefix = 2 full pages aliased by request 1
            assert eng.pool.stats.shares > 0
            assert eng.pool.stats.cow_copies >= 1
            # slab pages stay pinned after both requests finish
            assert eng.pool.pages_in_use > 0
            eng.prefix_pages.clear()
            assert eng.pool.pages_in_use == 0     # release hook fired
    assert outs["cold"] == outs["shared"]


def test_preemption_recompute_completes(small_rt):
    cfg, params = small_rt
    rng = np.random.default_rng(6)
    reqs = [ServeRequest(i, rng.integers(0, cfg.vocab, n).astype(np.int32),
                         m) for i, (n, m) in enumerate(
                             [(12, 6), (25, 8), (18, 5), (30, 7)])]
    coord = Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=3, capacity=64, paged=True,
                        page_size=PS, pages_per_engine=6)
    outs = coord.serve([ServeRequest(r.rid, r.prompt.copy(),
                                     r.max_new_tokens) for r in reqs])
    m = coord._active_session.metrics()
    assert sum(r.preemptions for r in m.requests) > 0   # pool forced it
    for o, r in zip(outs, reqs):
        assert len(o.tokens) == r.max_new_tokens
        assert o.lifecycle.phase.value == "done"


def test_coordinator_dense_equals_paged(small_rt):
    cfg, params = small_rt

    def mk():
        r = np.random.default_rng(8)
        return [ServeRequest(i, r.integers(0, cfg.vocab, n)
                             .astype(np.int32), m)
                for i, (n, m) in enumerate([(12, 5), (25, 7), (9, 4)])]

    dense_out = Coordinator(cfg, params, num_decode_engines=2,
                            slots_per_engine=2, capacity=64).serve(mk())
    coord = Coordinator(cfg, params, num_decode_engines=2,
                        slots_per_engine=2, capacity=64, paged=True,
                        page_size=PS)
    paged_out = coord.serve(mk())
    for a, b in zip(dense_out, paged_out):
        assert a.tokens == b.tokens
    m = coord._active_session.metrics()
    assert m.kv_pages_allocated == sum(
        pages_for_request(r.s_in, r.s_out, PS) for r in m.requests)
    assert 0.0 < m.page_utilization <= 1.0
    assert m.page_fragmentation == pytest.approx(1 - m.page_utilization)


# ---------------------------------------------------------------------------
# Per-page transfer / codec composition
# ---------------------------------------------------------------------------


def test_trim_to_pages_shapes(small_rt):
    cfg, params = small_rt
    pe = PrefillEngine(cfg, params, cache_capacity=64)
    prompt = np.arange(21, dtype=np.int32) % cfg.vocab
    _, slab = pe.prefill_batch([prompt])[0]
    trimmed = kv_transfer.trim_to_pages(slab, 21, PS, cfg=cfg)
    assert kv_transfer.slab_capacity(trimmed, cfg) == 32   # 2 pages
    grown = kv_transfer.trim_to_pages(trimmed, 40, PS, cfg=cfg)
    assert kv_transfer.slab_capacity(grown, cfg) == 48


def test_codec_composes_per_page(small_rt):
    """encode(slab) sliced per page == encode(per-page slices): the
    int8 per-head-vector scales are sequence-local, so transfer/chunk
    plans can land pages directly without re-encoding."""
    cfg, params = small_rt
    pe = PrefillEngine(cfg, params, cache_capacity=64)
    prompt = (np.arange(30, dtype=np.int32) * 13) % cfg.vocab
    _, slab = pe.prefill_batch([prompt])[0]
    slab = kv_transfer.trim_to_pages(slab, 30, PS, cfg=cfg)
    enc_then_split = kv_transfer.split_pages(
        kv_compression.encode(slab, cfg, "int8"), PS, cfg=cfg)
    split_then_enc = [kv_compression.encode(pg, cfg, "int8")
                      for pg in kv_transfer.split_pages(slab, PS, cfg=cfg)]
    for a, b in zip(enc_then_split, split_then_enc):
        la = jax.tree.leaves(a, is_leaf=lambda x: isinstance(
            x, kv_compression.QuantizedLeaf))
        lb = jax.tree.leaves(b, is_leaf=lambda x: isinstance(
            x, kv_compression.QuantizedLeaf))
        for x, y in zip(la, lb):
            if isinstance(x, kv_compression.QuantizedLeaf):
                np.testing.assert_array_equal(np.asarray(x.q),
                                              np.asarray(y.q))
                np.testing.assert_array_equal(np.asarray(x.scale),
                                              np.asarray(y.scale))
            else:
                np.testing.assert_array_equal(np.asarray(x),
                                              np.asarray(y))


def test_chunked_paged_admission_matches_plain(small_rt):
    """install_chunk over pages (period-sliced chunks landing in any
    order) must equal single-shot paged admission."""
    cfg, params = small_rt
    pe = PrefillEngine(cfg, params, cache_capacity=64)
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, 19).astype(np.int32)
    first, slab = pe.prefill_batch([prompt])[0]
    slab = kv_transfer.trim_to_pages(slab, 19, PS, cfg=cfg)
    outs = []
    for chunked in (False, True):
        eng = DecodeEngine(cfg, params, slots=2, capacity=64, paged=True,
                           page_size=PS)
        if chunked:
            plan = kv_compression.ChunkedTransferPlan.for_cache(slab, 2)
            chunks = list(zip((p0 for p0, _ in plan.bounds),
                              plan.split(slab)))
            eng.admit_chunked(0, first, 19, 4, reversed(chunks))
        else:
            eng.admit(0, first, 19, 4, slab)
        outs.append([eng.step() for _ in range(4)])
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Simulator paged model (no JAX required)
# ---------------------------------------------------------------------------


def _sim_placement():
    from repro.core import make_plan
    from repro.core.cluster import memory_skewed_setting
    from repro.core.cost_model import LLAMA2_70B
    from repro.core.placement import Placement, ReplicaPlacement
    cl = memory_skewed_setting()
    reps = [ReplicaPlacement(0, [2, 3, 4, 5], True,
                             make_plan([[2, 3, 4, 5]],
                                       LLAMA2_70B.num_layers, cl), 10.0),
            ReplicaPlacement(1, [0, 1], False,
                             make_plan([[0, 1]],
                                       LLAMA2_70B.num_layers, cl), 10.0)]
    return cl, LLAMA2_70B, Placement(reps, {(0, 1): 10.0}, 10.0, 600.0)


def test_sim_paged_stamps_match_arithmetic():
    from repro.serving import simulate
    from repro.serving.request import Request
    cl, prof, plc = _sim_placement()
    reqs = [Request(0, 16, 17, 0.0), Request(1, 17, 16, 0.0),
            Request(2, 31, 2, 0.0), Request(3, 32, 1, 0.0),
            Request(4, 200, 40, 0.0)]
    res = simulate(cl, prof, plc, reqs, paged_kv=True, page_size=PS)
    for r in reqs:
        assert r.kv_pages_allocated == pages_for_request(r.s_in, r.s_out,
                                                         PS), r.rid
    assert res.kv_pages_allocated == sum(
        pages_for_request(r.s_in, r.s_out, PS) for r in reqs)
    assert res.page_fragmentation == pytest.approx(
        1.0 - res.page_utilization)


def test_sim_paged_preemption_restarts_and_finishes():
    from repro.serving import offline_workload, simulate
    cl, prof, plc = _sim_placement()
    reqs = offline_workload("HPHD", 48, seed=3)
    res = simulate(cl, prof, plc, reqs, paged_kv=True, page_size=PS)
    assert all(r.decode_end is not None for r in reqs)
    # stamps still accumulate correctly for non-preempted requests
    for r in reqs:
        if r.preemptions == 0 and r.s_out > 1:
            assert r.kv_pages_allocated == pages_for_request(
                r.s_in, r.s_out, PS)
        elif r.preemptions:
            assert r.kv_pages_allocated > pages_for_request(
                r.s_in, r.s_out, PS) - 1
    assert res.decode_throughput > 0


def test_metric_fields_cover_page_schema():
    assert "page_utilization" in METRIC_FIELDS
    assert "page_fragmentation" in METRIC_FIELDS
    assert "kv_pages_allocated" in METRIC_FIELDS


def test_dense_sim_unchanged_without_paged_kv():
    """paged_kv=False must keep legacy results byte-for-byte."""
    from repro.serving import offline_workload, simulate
    cl, prof, plc = _sim_placement()
    a = simulate(cl, prof, plc, offline_workload("HPLD", 24, seed=1))
    b = simulate(cl, prof, plc, offline_workload("HPLD", 24, seed=1))
    assert a.makespan == b.makespan
    assert a.kv_pages_allocated == 0
    assert a.page_utilization == 1.0 and a.page_fragmentation == 0.0


# ---------------------------------------------------------------------------
# Review regressions
# ---------------------------------------------------------------------------


def test_sim_preemption_does_not_redecode_redone_tokens():
    """A §11 recompute charges redone tokens to the prefill; decode
    must produce each request's s_out exactly once in total."""
    from repro.core import make_plan
    from repro.core.cluster import memory_skewed_setting
    from repro.core.cost_model import LLAMA2_70B
    from repro.core.placement import Placement, ReplicaPlacement
    from repro.serving import offline_workload, simulate
    cl = memory_skewed_setting()
    # two prefill feeders swamp the memory-starved decode pair, so
    # resident-growth outruns the pool and preemption fires
    mk = lambda g: make_plan([g], LLAMA2_70B.num_layers, cl)
    reps = [ReplicaPlacement(0, [2, 3, 4, 5], True, mk([2, 3, 4, 5]), 10.0),
            ReplicaPlacement(2, [6, 7, 8, 9], True, mk([6, 7, 8, 9]), 10.0),
            ReplicaPlacement(1, [0, 1], False, mk([0, 1]), 10.0)]
    plc = Placement(reps, {(0, 1): 10.0, (2, 1): 10.0}, 20.0, 600.0)
    reqs = offline_workload("HPHD", 96, seed=3)
    res = simulate(cl, LLAMA2_70B, plc, reqs, paged_kv=True, page_size=PS)
    assert sum(r.preemptions for r in reqs) > 0   # the scenario fires
    assert all(r.decode_end is not None for r in reqs)
    assert res.decode_tokens == sum(r.s_out for r in reqs)


def test_paged_kernel_gate_admits_default_page_size():
    from repro.kernels import ops
    q = jnp.zeros((2, 1, 8, 64), jnp.bfloat16)
    pool16 = jnp.zeros((24, 16, 2, 64), jnp.bfloat16)
    assert ops.paged_decode_supported(q, pool16)
    pool9 = jnp.zeros((24, 9, 2, 64), jnp.bfloat16)
    assert not ops.paged_decode_supported(q, pool9)


def test_doomed_admit_does_not_wipe_prefix_radix(small_rt):
    """When every reclaimable page is aliased by active slots, a
    too-big admit must fail fast without evicting the radix."""
    cfg, params = small_rt
    pe = PrefillEngine(cfg, params, cache_capacity=96)
    # pool of 4 usable pages; one 33-token request holds 3 of them
    eng = DecodeEngine(cfg, params, slots=3, capacity=96, paged=True,
                       page_size=PS, num_pages=5, share_prefix_pages=True)
    rng = np.random.default_rng(13)
    p0 = rng.integers(0, cfg.vocab, 33).astype(np.int32)
    first, slab = pe.prefill_batch([p0])[0]
    eng.admit(0, first, 33, 4,
              kv_transfer.trim_to_pages(slab, 33, PS, cfg=cfg), tokens=p0)
    nodes_before = eng.prefix_pages.num_nodes
    assert nodes_before > 0
    # the slab's pages are all aliased by slot 0 -> nothing reclaimable
    assert not eng.can_admit(40)
    p1 = rng.integers(0, cfg.vocab, 40).astype(np.int32)
    first1, slab1 = pe.prefill_batch([p1])[0]
    with pytest.raises(OutOfPagesError):
        eng.admit(1, first1, 40, 4,
                  kv_transfer.trim_to_pages(slab1, 40, PS, cfg=cfg),
                  tokens=p1)
    assert eng.prefix_pages.num_nodes == nodes_before   # radix intact


def test_reservation_handoff_ships_only_unshared_blocks(small_rt):
    """Coordinator paged handoff with pool sharing: identical tokens,
    strictly fewer physical bytes on the wire (including the fully
    page-aligned prompt that ships an empty slab)."""
    cfg, params = small_rt
    prefix = (np.arange(32, dtype=np.int32) * 7) % cfg.vocab

    def mk():
        r = np.random.default_rng(12)
        reqs = []
        for i, tail_len in enumerate((7, 0, 5)):   # 0 = aligned prompt
            tail = r.integers(0, cfg.vocab, tail_len).astype(np.int32)
            reqs.append(ServeRequest(i, np.concatenate([prefix, tail]), 5))
        return reqs

    base_coord = Coordinator(cfg, params, num_decode_engines=1,
                             slots_per_engine=3, capacity=64, paged=True,
                             page_size=PS)
    base = base_coord.serve(mk())
    shared_coord = Coordinator(cfg, params, num_decode_engines=1,
                               slots_per_engine=3, capacity=64,
                               paged=True, page_size=PS,
                               prefix_cache_bytes=64e6)
    shared = shared_coord.serve(mk())
    for a, b in zip(base, shared):
        assert a.tokens == b.tokens
    s0 = base_coord._active_session
    s1 = shared_coord._active_session
    assert s1.kv_physical_bytes_raw < s0.kv_physical_bytes_raw
    assert shared_coord.decode_engines[0].pool.stats.shares > 0
