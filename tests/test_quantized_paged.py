"""Int8-resident paged KV (DESIGN.md §16): fused quantized kernel vs
oracle, arch-pool decode-logit accuracy, zero-requant wire→page install,
CoW scale-copy bit-identity, capacity accounting, and the cross-domain
``kv_cache_dtype``/page-count parity contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.kernels import ref
from repro.kernels.decode_attention import gqa_paged_decode_quant_bhsd
from repro.models import init_params, transformer
from repro.serving import (Coordinator, ServeRequest, kv_compression,
                           kv_transfer)
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.metrics import METRIC_FIELDS
from repro.serving.paging import pages_for_request

KEY = jax.random.PRNGKey(16)
PS = 16

#: The documented int8 accuracy contract (test_kv_compression.py): the
#: quantized path's decode logits stay within this max-abs delta of the
#: exact path on the reduced archs.
INT8_LOGIT_TOL = 0.15


def _quant_pool(key, npages, hkv, ps, hd):
    """Random float pages quantized to the §16 resident layout: int8
    codes + one fp32 symmetric scale per (page, kv-head)."""
    x = jax.random.normal(key, (npages, hkv, ps, hd), jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=(2, 3)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / s[:, :, None, None]),
                 -127, 127).astype(jnp.int8)
    return q, s


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------


QUANT_CASES = [
    # (b, hq, hkv, hd, page_size, num_blocks, num_pages)
    (1, 4, 4, 64, 16, 4, 8),
    (2, 8, 2, 64, 32, 8, 24),       # GQA group 4
    (3, 4, 1, 128, 16, 8, 32),      # MQA
]


@pytest.mark.parametrize("b,hq,hkv,hd,ps,nb,npages", QUANT_CASES)
def test_quant_paged_kernel_matches_oracle(b, hq, hkv, hd, ps, nb,
                                           npages):
    k1, k2, k3, k4, k5 = jax.random.split(KEY, 5)
    q = jax.random.normal(k1, (b, hq, hd), jnp.float32)
    kp, ks = _quant_pool(k2, npages, hkv, ps, hd)
    vp, vs = _quant_pool(k3, npages, hkv, ps, hd)
    bt = jax.random.randint(k4, (b, nb), 0, npages)
    vl = jax.random.randint(k5, (b,), 1, nb * ps + 1)
    out = gqa_paged_decode_quant_bhsd(q, kp, vp, ks, vs, bt, vl,
                                      interpret=True)
    expect = ref.gqa_paged_decode_quant_ref(q, kp, vp, ks, vs, bt, vl)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=1e-5, rtol=1e-5)


def test_quant_paged_kernel_ignores_pages_past_valid_len():
    """Rewriting pages AND scales past valid_len must not change the
    output — the fused dequant reads only live pages."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 4, 64), jnp.float32)
    kp, ks = _quant_pool(k2, 16, 2, 16, 64)
    vp, vs = _quant_pool(k3, 16, 2, 16, 64)
    bt = jnp.arange(2 * 6, dtype=jnp.int32).reshape(2, 6) % 16
    vl = jnp.array([20, 50])
    out1 = gqa_paged_decode_quant_bhsd(q, kp, vp, ks, vs, bt, vl,
                                       interpret=True)
    dead0, dead1 = jnp.asarray(bt[0, 2:]), jnp.asarray(bt[1, 4:])
    kp2 = kp.at[dead0].set(127).at[dead1].set(-128)
    vp2 = vp.at[dead0].set(-77)
    ks2 = ks.at[dead0].set(9.0)
    vs2 = vs.at[dead1].set(5.0)
    out2 = gqa_paged_decode_quant_bhsd(q, kp2, vp2, ks2, vs2, bt, vl,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_quant_paged_kernel_aot_lowers_for_tpu():
    qd = jax.ShapeDtypeStruct((4, 16, 128), jnp.bfloat16)
    pool = jax.ShapeDtypeStruct((64, 2, 16, 128), jnp.int8)
    sc = jax.ShapeDtypeStruct((64, 2), jnp.float32)
    bt = jax.ShapeDtypeStruct((4, 16), jnp.int32)
    vl = jax.ShapeDtypeStruct((4,), jnp.int32)
    tr = jax.jit(gqa_paged_decode_quant_bhsd).trace(qd, pool, pool, sc,
                                                    sc, bt, vl)
    txt = tr.lower(lowering_platforms=("tpu",)).as_text()
    assert "tpu_custom_call" in txt


# ---------------------------------------------------------------------------
# Arch-pool decode-logit accuracy (the §10 int8 tolerance contract)
# ---------------------------------------------------------------------------


def _mixed_swa(cfg):
    period = (cfg.period[0],
              dataclasses.replace(cfg.period[1], mixer="swa"))
    return dataclasses.replace(cfg, period=period, sliding_window=32,
                               name=cfg.name + "+swa")


ARCH_POOL = {
    "gqa": lambda: ARCHS["qwen3-1.7b"].reduced(),
    "moe": lambda: ARCHS["qwen3-moe-30b-a3b"].reduced(),
    "swa": lambda: _mixed_swa(ARCHS["llama4-maverick-400b-a17b"].reduced()),
    "jamba": lambda: ARCHS["jamba-v0.1-52b"].reduced(),
    "vision": lambda: ARCHS["llama-3.2-vision-90b"].reduced(),
    "kmajor": lambda: dataclasses.replace(
        ARCHS["qwen2.5-32b"].reduced(), kv_layout="kmajor",
        name="qwen2.5-32b-reduced-kmajor"),
}


@pytest.mark.parametrize("family", sorted(ARCH_POOL))
def test_int8_paged_decode_logits_within_tolerance(family):
    """Int8-resident paged decode logits stay within the documented
    ``INT8_LOGIT_TOL`` of the dense decode on every arch family — with
    the token trajectory pinned to the dense argmax so both caches see
    identical contexts, the only divergence is the quantization."""
    cfg = ARCH_POOL[family]()
    params = init_params(KEY, cfg)
    cap, steps = 64, 3
    extra = {}
    if cfg.num_image_tokens:
        extra["image_embeds"] = np.zeros(
            (1, cfg.num_image_tokens, cfg.d_model), np.float32)
    pe = PrefillEngine(cfg, params, cache_capacity=cap)
    dense = DecodeEngine(cfg, params, slots=2, capacity=cap)
    quant = DecodeEngine(cfg, params, slots=2, capacity=cap, paged=True,
                         page_size=PS, paged_dtype="int8")
    rng = np.random.default_rng(11)
    for rid, n in enumerate((13, 30)):    # 30 → crosses a page boundary
        prompt = rng.integers(0, cfg.vocab, n).astype(np.int32)
        first, slab = pe.prefill_batch([prompt], [extra])[0]
        dense.admit(rid, first, n, steps + 1,
                    kv_transfer.pad_capacity(slab, cap, cfg=cfg))
        quant.admit(rid, first, n, steps + 1,
                    kv_transfer.trim_to_pages(slab, n, PS, cfg=cfg))
    for _ in range(steps):
        for i, s in enumerate(quant.slots):   # table covers the write
            if s.active:
                quant._grow(i)
        pos = np.array([max(s.length - 1, 0) for s in dense.slots],
                       np.int32)
        toks = jnp.asarray(dense.tokens)[:, None]
        ld, _ = transformer.decode_step(
            params, cfg, dense.cache, toks, jnp.asarray(pos)[:, None])
        lq, _ = transformer.decode_step_paged(
            params, cfg, quant.cache, toks, jnp.asarray(pos)[:, None],
            jnp.asarray(quant.block_tables), PS)
        delta = np.max(np.abs(np.asarray(ld, np.float32)
                              - np.asarray(lq, np.float32)))
        assert delta <= INT8_LOGIT_TOL, (cfg.name, delta)
        dense.step()
        quant.step()
        quant.tokens[:] = dense.tokens    # pin trajectories together


def test_bf16_paged_unchanged_when_mode_off():
    """paged_dtype=None keeps the §11 pytree and behavior untouched:
    no scale sidecar, model-dtype pools, and engine decode bitwise
    equal to dense — the off-mode regression gate."""
    cfg = ARCHS["qwen3-1.7b"].reduced()
    cache = transformer.init_paged_cache(cfg, 2, 8, PS)
    for spec, c in zip(cfg.period, cache):
        if spec.mixer == "attn":
            assert set(c) == {"k", "v"}
            assert c["k"].dtype != jnp.int8
    qcache = transformer.init_paged_cache(cfg, 2, 8, PS,
                                          paged_dtype="int8")
    for spec, c in zip(cfg.period, qcache):
        if spec.mixer == "attn":
            assert set(c) == {"k", "v", "k_scale", "v_scale"}
            assert c["k"].dtype == jnp.int8
            assert c["k_scale"].dtype == jnp.float32
    with pytest.raises(ValueError):
        DecodeEngine(cfg, init_params(KEY, cfg), slots=1, capacity=32,
                     paged=True, paged_dtype="fp4")


# ---------------------------------------------------------------------------
# Zero-requant wire → page install (§10 × §16)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_rt():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    return cfg, init_params(KEY, cfg)


def test_zero_requant_install_matches_quantize_once(small_rt):
    """Admitting the int8 WIRE form (still-encoded QuantizedLeaf slab)
    must land the same page scales as quantizing the float slab once
    (page scale = max of the row scales; equal up to one fp32 division
    ulp — the wire codec's jitted amax/127 is a reciprocal-multiply),
    codes within one renormalization ulp, and an identical decode
    trajectory — the dequant→requant round-trip this path replaces
    loses a full quantization step, not an ulp."""
    cfg, params = small_rt
    pe = PrefillEngine(cfg, params, cache_capacity=64)
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, cfg.vocab, 27).astype(np.int32)
    first, slab = pe.prefill_batch([prompt])[0]
    slab = kv_transfer.trim_to_pages(slab, 27, PS, cfg=cfg)
    encoded = kv_compression.encode(slab, cfg, "int8")

    raw = DecodeEngine(cfg, params, slots=2, capacity=64, paged=True,
                       page_size=PS, paged_dtype="int8")
    wire = DecodeEngine(cfg, params, slots=2, capacity=64, paged=True,
                        page_size=PS, paged_dtype="int8")
    raw.admit(0, first, 27, 5, slab)
    wire.admit(0, first, 27, 5, encoded)
    for spec, a, b in zip(cfg.period, raw.cache, wire.cache):
        if spec.mixer != "attn":
            continue
        for nm in ("k_scale", "v_scale"):
            np.testing.assert_allclose(np.asarray(a[nm]),
                                       np.asarray(b[nm]), rtol=2e-7,
                                       err_msg=nm)
        for nm in ("k", "v"):
            d = np.abs(np.asarray(a[nm], np.int32)
                       - np.asarray(b[nm], np.int32))
            assert d.max() <= 1, (nm, d.max())
    for _ in range(5):
        assert raw.step() == wire.step()


def test_chunked_wire_install_matches_whole_slab(small_rt):
    """admit_chunked over ENCODED chunks (the §10 int8-chunked stream
    landing page-scattered, any order) is bitwise the whole-encoded
    admit — the coordinator's zero-requant streaming path."""
    cfg, params = small_rt
    pe = PrefillEngine(cfg, params, cache_capacity=64)
    rng = np.random.default_rng(22)
    prompt = rng.integers(0, cfg.vocab, 19).astype(np.int32)
    first, slab = pe.prefill_batch([prompt])[0]
    slab = kv_transfer.trim_to_pages(slab, 19, PS, cfg=cfg)
    encoded = kv_compression.encode(slab, cfg, "int8")
    whole = DecodeEngine(cfg, params, slots=2, capacity=64, paged=True,
                         page_size=PS, paged_dtype="int8")
    chunked = DecodeEngine(cfg, params, slots=2, capacity=64, paged=True,
                           page_size=PS, paged_dtype="int8")
    whole.admit(0, first, 19, 4, encoded)
    plan = kv_compression.ChunkedTransferPlan.for_cache(encoded, 2)
    chunks = list(zip((p0 for p0, _ in plan.bounds), plan.split(encoded)))
    chunked.admit_chunked(0, first, 19, 4, reversed(chunks))
    for a, b in zip(jax.tree.leaves(whole.cache),
                    jax.tree.leaves(chunked.cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for _ in range(4):
        assert whole.step() == chunked.step()


def test_cow_scale_copy_bit_identical(small_rt):
    """§16 CoW over int8 pages: a shared-prefix engine must decode
    bitwise like a cold one — the boundary-page copy carries the fp32
    scale sidecar along with the int8 payload."""
    cfg, params = small_rt
    pe = PrefillEngine(cfg, params, cache_capacity=96)
    rng = np.random.default_rng(23)
    prefix = rng.integers(0, cfg.vocab, 37).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(0, cfg.vocab, k)
                               .astype(np.int32)]) for k in (5, 9)]
    outs = {}
    for mode in ("cold", "shared"):
        eng = DecodeEngine(cfg, params, slots=2, capacity=96, paged=True,
                           page_size=PS, paged_dtype="int8",
                           share_prefix_pages=(mode == "shared"))
        for rid, p in enumerate(prompts):
            first, slab = pe.prefill_batch([p])[0]
            eng.admit(rid, first, len(p), 5,
                      kv_transfer.trim_to_pages(slab, len(p), PS, cfg=cfg),
                      tokens=p)
        outs[mode] = [eng.step() for _ in range(5)]
        if mode == "shared":
            assert eng.pool.stats.shares > 0
            assert eng.pool.stats.cow_copies >= 1
    assert outs["cold"] == outs["shared"]


# ---------------------------------------------------------------------------
# Coordinator end to end + cross-domain parity
# ---------------------------------------------------------------------------


def _mk_reqs(cfg, seed=31):
    r = np.random.default_rng(seed)
    return [ServeRequest(i, r.integers(0, cfg.vocab, n).astype(np.int32),
                         m) for i, (n, m) in enumerate(
                             [(12, 5), (25, 7), (9, 4)])]


def test_coordinator_int8_paged_end_to_end(small_rt):
    """Full serve with int8-resident pools (raw and int8-chunked wire):
    every request completes, the metrics stamp ``kv_cache_dtype``, and
    the page counts keep the §11 arithmetic exactly."""
    cfg, params = small_rt
    base = Coordinator(cfg, params, num_decode_engines=2,
                       slots_per_engine=2, capacity=64, paged=True,
                       page_size=PS).serve(_mk_reqs(cfg))
    for codec in (None, "int8-chunked"):
        coord = Coordinator(cfg, params, num_decode_engines=2,
                            slots_per_engine=2, capacity=64, paged=True,
                            page_size=PS, paged_dtype="int8",
                            kv_codec=codec)
        outs = coord.serve(_mk_reqs(cfg))
        for a, b in zip(base, outs):
            assert len(b.tokens) == len(a.tokens)
        m = coord._active_session.metrics()
        assert m.kv_cache_dtype == "int8"
        assert m.kv_pages_allocated == sum(
            pages_for_request(r.s_in, r.s_out, PS) for r in m.requests)
        assert 0.0 < m.page_utilization <= 1.0
    bm = Coordinator(cfg, params, num_decode_engines=2,
                     slots_per_engine=2, capacity=64, paged=True,
                     page_size=PS)
    bm.serve(_mk_reqs(cfg))
    assert bm._active_session.metrics().kv_cache_dtype is None


def _sim_placement():
    from repro.core import make_plan
    from repro.core.cluster import memory_skewed_setting
    from repro.core.cost_model import LLAMA2_70B
    from repro.core.placement import Placement, ReplicaPlacement
    cl = memory_skewed_setting()
    reps = [ReplicaPlacement(0, [2, 3, 4, 5], True,
                             make_plan([[2, 3, 4, 5]],
                                       LLAMA2_70B.num_layers, cl), 10.0),
            ReplicaPlacement(1, [0, 1], False,
                             make_plan([[0, 1]],
                                       LLAMA2_70B.num_layers, cl), 10.0)]
    return cl, LLAMA2_70B, Placement(reps, {(0, 1): 10.0}, 10.0, 600.0)


def test_sim_runtime_page_count_and_dtype_parity(small_rt):
    """The parity contract: for the same (s_in, s_out) trace both
    domains report the SAME page totals (both reduce to
    ``pages_for_request``) and the same ``kv_cache_dtype`` stamp."""
    from repro.serving import simulate
    from repro.serving.request import Request
    cfg, params = small_rt
    coord = Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=3, capacity=64, paged=True,
                        page_size=PS, paged_dtype="int8")
    coord.serve(_mk_reqs(cfg))
    m = coord._active_session.metrics()
    cl, prof, plc = _sim_placement()
    reqs = [Request(r.rid, r.s_in, r.s_out, 0.0) for r in m.requests]
    res = simulate(cl, prof, plc, reqs, paged_kv=True, page_size=PS,
                   kv_cache_dtype="int8")
    assert res.kv_cache_dtype == m.kv_cache_dtype == "int8"
    assert res.kv_pages_allocated == m.kv_pages_allocated
    assert "kv_cache_dtype" in METRIC_FIELDS


# ---------------------------------------------------------------------------
# Capacity accounting (cost model + pool bytes)
# ---------------------------------------------------------------------------


def test_kv_page_bytes_int8_accounting():
    from repro.core.cost_model import LLAMA2_70B, kv_page_bytes
    p = LLAMA2_70B
    b = kv_page_bytes(p, PS)
    assert b == kv_page_bytes(p, PS, kv_cache_dtype=None)   # off == §11
    assert b == (PS * p.kv_bytes_token_layer * p.num_layers
                 * p.attn_layer_fraction)
    q = kv_page_bytes(p, PS, kv_cache_dtype="int8")
    elems = p.kv_bytes_token_layer / p.kv_elem_bytes
    expect = ((PS * elems * 1.0 + elems / p.kv_quant_group * 4.0)
              * p.num_layers * p.attn_layer_fraction)
    assert q == pytest.approx(expect)
    assert q < b                       # int8 + sidecar beats bf16
    assert q > b / p.kv_elem_bytes     # but the sidecar is charged


def test_int8_pages_raise_decode_budget_and_concurrency():
    from repro.core.cost_model import (LLAMA2_70B, WORKLOADS,
                                      decode_page_budget,
                                      max_decode_batch_paged)
    cl, prof, plc = _sim_placement()
    dec = next(r for r in plc.replicas if not r.is_prefill)
    budget_b = decode_page_budget(cl, prof, dec.plan, PS)
    budget_q = decode_page_budget(cl, prof, dec.plan, PS,
                                  kv_cache_dtype="int8")
    assert budget_q > budget_b * 1.5   # ~2x pages at equal HBM
    wl = WORKLOADS["HPHD"]
    cc_b = max_decode_batch_paged(cl, prof, dec.plan, wl, PS)
    cc_q = max_decode_batch_paged(cl, prof, dec.plan, wl, PS,
                                  kv_cache_dtype="int8")
    assert cc_q >= cc_b
    # dense-slab pricing must IGNORE the resident dtype (§16)
    assert max_decode_batch_paged(cl, prof, dec.plan, wl, PS,
                                  slot_capacity=1024,
                                  kv_cache_dtype="int8") \
        == max_decode_batch_paged(cl, prof, dec.plan, wl, PS,
                                  slot_capacity=1024)


def test_prefix_budget_counts_scale_sidecar(small_rt):
    """Engine pool byte metadata (what prefix budgets are charged
    against) must include the fp32 sidecar, and the cost model's
    per-token prefix pricing must agree with its page pricing."""
    from repro.core.cost_model import (LLAMA2_70B, kv_page_bytes,
                                      prefix_bytes_per_token)
    cfg, params = small_rt
    bf16 = DecodeEngine(cfg, params, slots=1, capacity=32, paged=True,
                        page_size=PS)
    q = DecodeEngine(cfg, params, slots=1, capacity=32, paged=True,
                     page_size=PS, paged_dtype="int8")
    assert q.pool.dtype == "int8" and bf16.pool.dtype is None
    assert q.pool.page_bytes < bf16.pool.page_bytes
    # payload alone would be half the bf16 page; the sidecar is extra
    kv_elem = jnp.zeros((), bf16.cache[0]["k"].dtype).dtype.itemsize
    assert q.pool.page_bytes > bf16.pool.page_bytes / kv_elem
    assert prefix_bytes_per_token(LLAMA2_70B, kv_cache_dtype="int8",
                                  page_size=PS) == pytest.approx(
        kv_page_bytes(LLAMA2_70B, PS, kv_cache_dtype="int8") / PS)
