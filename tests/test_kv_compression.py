"""KV codec round-trips (DESIGN.md §10): exact codec bit-identity
through the handoff, int8 leaf-role exemptions, decode-logit accuracy
on the attention archs, chunked split/join + chunked decode-engine
admission, and the runtime session end to end."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import decode_step, init_params, prefill
from repro.serving import (Coordinator, DecodeEngine, ServeRequest,
                           kv_compression as kc, kv_transfer)

KEY = jax.random.PRNGKey(3)

#: Documented int8 accuracy contract: after a quantized handoff, the
#: next decode step's logits stay within this max-abs delta of the
#: exact-handoff logits on the reduced attention archs (measured ≤0.05
#: on logit scales ~3.6; the bound leaves 3x headroom).
INT8_LOGIT_TOL = 0.15


def _prefilled(name, batch=2, seq=8, capacity=16):
    cfg = ARCHS[name].reduced()
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (batch, seq), 0, cfg.vocab)
    extra = {}
    if cfg.num_image_tokens:
        extra["image_embeds"] = jnp.zeros(
            (batch, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    logits, cache = prefill(params, cfg, toks, cache_capacity=capacity,
                            **extra)
    return cfg, params, toks, logits, cache


# -- codec resolution -------------------------------------------------------


def test_get_codec_resolution():
    assert kc.get_codec(None).name == "none"
    assert kc.get_codec("int8").quantize
    c = kc.get_codec("int8-chunked")
    assert c.chunked and c.chunks > 1
    assert kc.get_codec(c) is c
    with pytest.raises(KeyError):
        kc.get_codec("zstd")


# -- exact codec ------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "xlstm-125m"])
def test_none_codec_bit_identical_through_transfer(arch):
    cfg, _, _, _, cache = _prefilled(arch)
    out = kv_transfer.transfer(cache, codec="none", cfg=cfg)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- int8 role exemptions ---------------------------------------------------


def _roles_of(tree, cfg, encoded):
    roles = {}

    def visit(path, leaf):
        roles[tuple(str(p) for p in path)] = kv_transfer.leaf_role(
            path, leaf, cfg)

    jax.tree_util.tree_map_with_path(visit, tree)
    return roles


@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "llama-3.2-vision-90b"])
def test_int8_exempts_state_and_cross_leaves(arch):
    """mamba conv/ssm state and cross-attention memory must pass
    through the int8 codec untouched (leaf_role classification)."""
    cfg, _, _, _, cache = _prefilled(arch)
    enc = kc.encode(cache, cfg, "int8")
    flat_raw = jax.tree_util.tree_flatten_with_path(cache)[0]
    quantized, exempt = 0, 0
    for (path, leaf), enc_leaf in zip(
            flat_raw,
            jax.tree.leaves(enc, is_leaf=lambda x:
                            isinstance(x, kc.QuantizedLeaf))):
        role = kv_transfer.leaf_role(path, leaf, cfg)
        if role in kc.QUANT_ROLES and jnp.issubdtype(leaf.dtype,
                                                     jnp.floating):
            assert isinstance(enc_leaf, kc.QuantizedLeaf), (path, role)
            quantized += 1
        else:
            assert not isinstance(enc_leaf, kc.QuantizedLeaf), (path, role)
            np.testing.assert_array_equal(np.asarray(leaf),
                                          np.asarray(enc_leaf))
            exempt += 1
    assert quantized > 0, "arch must have quantizable attention KV"
    assert exempt > 0, "arch must have exempt (state/cross) leaves"
    # decode restores shapes/dtypes everywhere
    dec = kc.decode(enc)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(dec)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_int8_quantizes_swa_window_but_not_pos_ring():
    cfg = ARCHS["qwen3-1.7b"].with_sliding_window(64).reduced()
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab)
    _, cache = prefill(params, cfg, toks, cache_capacity=16)
    enc = kc.encode(cache, cfg, "int8")
    kinds = {}

    def visit(path, leaf):
        kinds[kv_transfer.leaf_role(path, leaf, cfg)] = True

    jax.tree_util.tree_map_with_path(visit, cache)
    assert "window_kv" in kinds and "window_pos" in kinds
    leaves = jax.tree.leaves(enc, is_leaf=lambda x:
                             isinstance(x, kc.QuantizedLeaf))
    assert any(isinstance(l, kc.QuantizedLeaf) for l in leaves)
    # the int32 position ring must never be quantized
    assert all(not isinstance(l, kc.QuantizedLeaf)
               for l in leaves if getattr(l, "dtype", None) == jnp.int32)


# -- decode-logit accuracy contract -----------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "yi-34b", "qwen2.5-32b"])
def test_int8_decode_logits_within_tolerance(arch):
    cfg, params, _, logits_p, cache = _prefilled(arch)
    rt = kv_transfer.transfer(cache, codec="int8", cfg=cfg)
    nxt = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((nxt.shape[0], 1), 8, jnp.int32)
    ref, _ = decode_step(params, cfg, cache, nxt, pos)
    got, _ = decode_step(params, cfg, rt, nxt, pos)
    delta = np.max(np.abs(np.asarray(ref, np.float32)
                          - np.asarray(got, np.float32)))
    assert delta <= INT8_LOGIT_TOL, delta


# -- byte accounting --------------------------------------------------------


def test_quantizing_codec_requires_cfg():
    """Without declared leaf roles, the name heuristic would classify
    cross-attention memory as quantizable KV — quantizing codecs must
    refuse to run cfg-less instead of silently degrading decode."""
    cfg, _, _, _, cache = _prefilled("llama-3.2-vision-90b")
    with pytest.raises(ValueError):
        kc.encode(cache, None, "int8")
    with pytest.raises(ValueError):
        kv_transfer.transfer(cache, codec="int8")
    with pytest.raises(ValueError):
        kv_transfer.transfer_bytes(cache, codec="int8")
    # exact codecs never need cfg
    kv_transfer.transfer(cache, codec="none")
    assert kc.encode(cache, None, "none") is cache


def test_transfer_bytes_analytic_matches_encoded():
    cfg, _, _, _, cache = _prefilled("qwen3-1.7b")
    enc = kc.encode(cache, cfg, "int8")
    assert kv_transfer.transfer_bytes(cache, codec="int8", cfg=cfg) \
        == kc.encoded_bytes(enc)
    assert kv_transfer.transfer_bytes(cache, codec="none") \
        == kv_transfer.transfer_bytes(cache) == kc.encoded_bytes(cache)
    assert kc.encoded_bytes(enc) < kv_transfer.transfer_bytes(cache)


def test_profile_accounting_consistency():
    from repro.core.cost_model import ModelProfile
    from repro.models.common import DEFAULT_DTYPE
    cfg = ARCHS["qwen3-1.7b"].reduced()
    prof = ModelProfile.from_arch(cfg, kv_dtype=DEFAULT_DTYPE)
    raw = kc.profile_raw_bytes(prof, 100)
    wire = kc.profile_wire_bytes(prof, 100, "int8")
    assert wire < raw
    assert raw / wire == pytest.approx(kc.profile_kv_ratio(prof, "int8"))
    # exact codec: identical accounting
    assert kc.profile_wire_bytes(prof, 100, "none") == raw
    assert kc.profile_kv_ratio(prof, None) == 1.0


# -- chunked streaming ------------------------------------------------------


@pytest.mark.parametrize("codec", ["none", "int8"])
def test_chunked_split_join_identity(codec):
    cfg, _, _, _, cache = _prefilled("qwen3-1.7b")
    tree = kc.encode(cache, cfg, codec)
    plan = kc.ChunkedTransferPlan.for_cache(tree, 8)
    assert 1 <= plan.num_chunks <= 8
    assert plan.bounds[0][0] == 0
    assert all(a[1] == b[0] for a, b in zip(plan.bounds, plan.bounds[1:]))
    joined = plan.join(plan.split(tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(joined)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_admit_chunked_equals_admit():
    cfg, params, _, logits_p, cache = _prefilled("qwen3-1.7b", batch=1,
                                                 capacity=16)
    one = kv_transfer.slice_request(cache, 0)
    first = int(np.asarray(jnp.argmax(logits_p, -1))[0])
    eng_full = DecodeEngine(cfg, params, slots=2, capacity=16)
    eng_chunk = DecodeEngine(cfg, params, slots=2, capacity=16)
    eng_full.admit(0, first, 8, 4, one)
    plan = kc.ChunkedTransferPlan.for_cache(one, 4)
    eng_chunk.admit_chunked(0, first, 8, 4,
                            ((p0, chunk) for (p0, _), chunk in
                             zip(plan.bounds, plan.split(one))))
    for a, b in zip(jax.tree.leaves(eng_full.cache),
                    jax.tree.leaves(eng_chunk.cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and decoding proceeds identically
    for _ in range(3):
        sa, sb = eng_full.step(), eng_chunk.step()
        assert sa == sb


# -- runtime session end to end ---------------------------------------------


def _serve(cfg, params, prompts, codec):
    coord = Coordinator(cfg, params, num_decode_engines=2,
                        slots_per_engine=2, capacity=24, kv_codec=codec)
    res = coord.serve([ServeRequest(i, p, 6)
                       for i, p in enumerate(prompts)])
    return [r.tokens for r in res], coord._active_session.metrics()


def test_session_codecs_end_to_end():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
               for _ in range(4)]
    toks_default, m_default = _serve(cfg, params, prompts, None)
    toks_none, m_none = _serve(cfg, params, prompts, "none")
    toks_chunked, m_chunked = _serve(cfg, params, prompts, "int8-chunked")
    # exact codec is bit-identical to the default path
    assert toks_default == toks_none
    assert m_none.kv_compression_ratio == 1.0
    assert m_none.kv_bytes_shipped > 0
    # int8-chunked ships fewer accounted bytes and every request completes
    assert m_chunked.kv_bytes_shipped < m_none.kv_bytes_shipped
    assert m_chunked.kv_compression_ratio > 1.5
    assert all(len(t) == 6 for t in toks_chunked)
